//! Device-batched chunk encoding: a [`FeatureEncoder`] that offloads the
//! bbit/vw hash kernels to the AOT-compiled PJRT artifacts
//! (`preprocess --device xla`), with the CPU kernels as the always-on
//! fallback.
//!
//! The paper's headline follow-up is that accelerator preprocessing
//! collapses the hashing cost ("by using a GPU, the preprocessing cost
//! can be reduced to a small fraction of the data loading time"; see also
//! arXiv:1205.2958).  This module is that wiring: the pipeline workers
//! keep parsing byte blocks exactly as before, but `encode_parsed` pads
//! each chunk's CSR rows to the artifact's compiled `[batch, nnz]`
//! geometry and launches the device kernel instead of the scalar loop.
//!
//! ## Threading model
//!
//! The PJRT client is not `Sync` (and is treated as not `Send`), so it
//! never crosses threads.  [`DeviceEncoder::new`] spawns one dedicated
//! driver thread that owns the [`PjrtRuntime`] + engine for the
//! encoder's lifetime; pipeline workers talk to it over a bounded job
//! channel carrying pre-padded `idx`/`mask` slabs (plain `Vec<i32>`, so
//! nothing device-owned crosses threads).  Each worker keeps up to two
//! batches in flight and pads the next slab while the driver executes
//! the previous one — host→device literal construction overlaps compute
//! (the double buffer), and the driver hands slabs back for reuse, so
//! steady state allocates nothing per batch.
//!
//! ## Fallback and parity
//!
//! Construction never fails for device reasons: when the artifacts dir
//! is absent, no artifact matches the spec's geometry, the scheme has no
//! device kernel, or compilation fails, the encoder logs the reason once
//! and runs every chunk on the CPU.  Rows a batch cannot carry (more
//! than `nnz` nonzeros, or indices above `i32::MAX`) are computed with
//! the CPU twin straight into their output slot — safe to mix because
//! the device kernels are bit-exact against the CPU hashers (asserted in
//! `tests/device_encoder.rs`): minwise values reduce mod the same
//! `d_space` the CPU family uses, and the VW kernel's ±1 bin sums are
//! exact in f32, so packed codes and sparse rows — and therefore caches
//! written through `--device xla` — are byte-identical to the CPU path.

use std::collections::VecDeque;
use std::path::{Path, PathBuf};
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::mpsc::{self, Receiver, SyncSender};
use std::sync::{Arc, Mutex};
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

use crate::data::dataset::Example;
use crate::data::libsvm::ParsedChunk;
use crate::encode::encoder::{
    set_encode_used_device, DeviceStatsSnapshot, EncodeScratch, EncodedChunk, EncoderSpec,
    FeatureEncoder,
};
use crate::encode::packed::PackedCodes;
use crate::hashing::minwise::BbitMinHash;
use crate::hashing::vw::VwHasher;
use crate::runtime::manifest::ArtifactSpec;
use crate::runtime::{MinhashEngine, PjrtRuntime, VwEngine};
use crate::util::Rng;
use crate::{Error, Result};

/// Monotonic handle ids: the per-thread staging state keys its cached
/// job-channel sender on this, so sequential `DeviceEncoder`s in one
/// process never cross-contaminate.
static HANDLE_IDS: AtomicU64 = AtomicU64::new(1);

/// One padded launch: `[batch, nnz]` idx/mask slabs in, hash output plus
/// the same slabs (for reuse) out.
struct DeviceJob {
    idx: Vec<i32>,
    mask: Vec<i32>,
    reply: mpsc::Sender<Result<DeviceBatchOut>>,
}

enum DeviceOut {
    /// Row-major `[batch, k]` minwise values.
    Minhash(Vec<i32>),
    /// Row-major `[batch, bins]` dense signed-sum vectors.
    Vw(Vec<f32>),
}

struct DeviceBatchOut {
    out: DeviceOut,
    idx: Vec<i32>,
    mask: Vec<i32>,
}

/// The engine the driver thread owns.  `_rt` keeps the PJRT client (and
/// its compiled-executable cache) alive for as long as the engines are.
struct DriverEngine {
    _rt: PjrtRuntime,
    kind: EngineKind,
}

enum EngineKind {
    Minhash { eng: MinhashEngine, c1: Vec<u32>, c2: Vec<u32> },
    Vw { eng: VwEngine, params: [u32; 4] },
}

/// The matching artifact with the largest padded nnz (padding waste only
/// hurts throughput, while a too-small nnz forces per-row CPU fallbacks —
/// prefer capacity).
fn best_artifact(rt: &PjrtRuntime, matches: impl Fn(&ArtifactSpec) -> bool) -> Option<String> {
    rt.manifest
        .artifacts
        .iter()
        .filter(|(_, s)| matches(s))
        .max_by_key(|(_, s)| s.consts.get("nnz").copied().unwrap_or(0))
        .map(|(name, _)| name.clone())
}

impl DriverEngine {
    /// Runs on the driver thread; every failure is a reason string the
    /// constructor logs before falling back to CPU.
    fn build(dir: &Path, spec: &EncoderSpec) -> std::result::Result<Self, String> {
        let rt = PjrtRuntime::cpu(dir).map_err(|e| format!("PJRT runtime unavailable: {e}"))?;
        let kind = match *spec {
            EncoderSpec::Bbit { b, k, d, seed } => {
                let name = best_artifact(&rt, |s| {
                    s.consts.get("k") == Some(&(k as i64))
                        && s.consts.get("d_space") == Some(&(d as i64))
                        && s.consts.contains_key("nnz")
                        && s.consts.contains_key("batch")
                })
                .ok_or_else(|| {
                    format!("no minhash artifact matches k={k} d_space={d} in {}", dir.display())
                })?;
                let eng = MinhashEngine::new(&rt, &name)
                    .map_err(|e| format!("compiling {name}: {e}"))?;
                // the identical draw sequence EncoderSpec::encoder() uses,
                // so the device launch carries the exact same family
                let hasher = BbitMinHash::draw(k, b, d, &mut Rng::new(seed));
                let (c1, c2) = hasher.hasher.family.param_arrays();
                EngineKind::Minhash { eng, c1, c2 }
            }
            EncoderSpec::Vw { bins, seed } => {
                let name = best_artifact(&rt, |s| {
                    s.consts.get("bins") == Some(&(bins as i64))
                        && s.consts.contains_key("nnz")
                        && s.consts.contains_key("batch")
                })
                .ok_or_else(|| {
                    format!("no vw artifact matches bins={bins} in {}", dir.display())
                })?;
                let eng =
                    VwEngine::new(&rt, &name).map_err(|e| format!("compiling {name}: {e}"))?;
                let params = VwHasher::draw(bins, &mut Rng::new(seed)).param_array();
                EngineKind::Vw { eng, params }
            }
            ref other => return Err(format!("scheme {} has no device kernel", other.scheme())),
        };
        Ok(DriverEngine { _rt: rt, kind })
    }

    fn geometry(&self) -> (usize, usize) {
        match &self.kind {
            EngineKind::Minhash { eng, .. } => (eng.batch, eng.nnz),
            EngineKind::Vw { eng, .. } => (eng.batch, eng.nnz),
        }
    }

    fn serve(&self, job: DeviceJob) {
        let DeviceJob { idx, mask, reply } = job;
        // failpoint: an injected launch failure surfaces exactly like a
        // PJRT execute error — the worker falls back to the CPU twin for
        // the whole chunk, and the output stays bit-identical
        if let Err(e) = crate::faults::fail(crate::faults::site::DEVICE_LAUNCH) {
            let _ = reply.send(Err(e));
            return;
        }
        let result = match &self.kind {
            EngineKind::Minhash { eng, c1, c2 } => {
                eng.minhash_padded(&idx, &mask, c1, c2).map(DeviceOut::Minhash)
            }
            EngineKind::Vw { eng, params } => {
                eng.hash_padded(&idx, &mask, *params).map(DeviceOut::Vw)
            }
        };
        // a dropped receiver means the worker already gave up on this
        // chunk (CPU fallback) — nothing to do
        let _ = reply.send(result.map(|out| DeviceBatchOut { out, idx, mask }));
    }
}

fn run_driver(
    dir: PathBuf,
    spec: EncoderSpec,
    ready: mpsc::Sender<std::result::Result<(usize, usize), String>>,
    jobs: Receiver<DeviceJob>,
    stop: Arc<AtomicBool>,
) {
    let engine = match DriverEngine::build(&dir, &spec) {
        Ok(e) => e,
        Err(reason) => {
            let _ = ready.send(Err(reason));
            return;
        }
    };
    let _ = ready.send(Ok(engine.geometry()));
    // recv_timeout + stop flag instead of plain recv: worker threads'
    // staging state holds cloned senders in thread-local storage, so the
    // channel may outlive the handle — the flag bounds shutdown anyway
    loop {
        match jobs.recv_timeout(Duration::from_millis(25)) {
            Ok(job) => engine.serve(job),
            Err(mpsc::RecvTimeoutError::Timeout) => {
                if stop.load(Ordering::Relaxed) {
                    return;
                }
            }
            Err(mpsc::RecvTimeoutError::Disconnected) => return,
        }
    }
}

/// The live driver-thread connection.
struct DeviceHandle {
    tx: Mutex<Option<SyncSender<DeviceJob>>>,
    driver: Mutex<Option<JoinHandle<()>>>,
    stop: Arc<AtomicBool>,
    /// Compiled documents-per-launch.
    batch: usize,
    /// Compiled padded nonzeros per document.
    nnz: usize,
    id: u64,
}

impl Drop for DeviceHandle {
    fn drop(&mut self) {
        self.stop.store(true, Ordering::Relaxed);
        *self.tx.lock().unwrap() = None;
        if let Some(h) = self.driver.lock().unwrap().take() {
            let _ = h.join();
        }
    }
}

/// Recyclable `[batch, nnz]` slab pairs handed back by the driver.
type FreeSlabs = Vec<(Vec<i32>, Vec<i32>)>;
/// Submitted batches awaiting results: (chunk row ids, reply receiver).
type Inflight = VecDeque<(Vec<usize>, Receiver<Result<DeviceBatchOut>>)>;

/// Per-worker-thread staging state: the cached sender, recycled slabs,
/// and CPU-twin scratch.  Thread-local so the pipeline workers share the
/// encoder by `&self` without locks on the hot path.
struct Staging {
    handle_id: u64,
    tx: Option<SyncSender<DeviceJob>>,
    free: FreeSlabs,
    /// Flat `[n, k]` b-bit codes for the chunk being assembled.
    codes: Vec<u16>,
    /// CPU-twin scratch (minwise values / one code row / vw pairs).
    z: Vec<u64>,
    row: Vec<u16>,
    pairs: Vec<(u32, f32)>,
}

thread_local! {
    static STAGING: std::cell::RefCell<Staging> = const {
        std::cell::RefCell::new(Staging {
            handle_id: 0,
            tx: None,
            free: Vec::new(),
            codes: Vec::new(),
            z: Vec::new(),
            row: Vec::new(),
            pairs: Vec::new(),
        })
    };
}

/// One batch being staged on a worker thread.
struct Batch {
    idx: Vec<i32>,
    mask: Vec<i32>,
    rows: Vec<usize>,
}

impl Batch {
    fn acquire(free: &mut FreeSlabs, cap: usize) -> Batch {
        while let Some((idx, mut mask)) = free.pop() {
            if idx.len() == cap {
                // stale idx values are dead weight — the kernel masks them
                mask.fill(0);
                return Batch { idx, mask, rows: Vec::new() };
            }
        }
        Batch { idx: vec![0; cap], mask: vec![0; cap], rows: Vec::new() }
    }

    fn stage(&mut self, nnz: usize, row_id: usize, set: &[u32]) {
        let base = self.rows.len() * nnz;
        for (c, &t) in set.iter().enumerate() {
            self.idx[base + c] = t as i32;
            self.mask[base + c] = 1;
        }
        self.rows.push(row_id);
    }
}

fn submit(tx: &SyncSender<DeviceJob>, batch: Batch, inflight: &mut Inflight) -> Result<()> {
    let (reply_tx, reply_rx) = mpsc::channel();
    tx.send(DeviceJob { idx: batch.idx, mask: batch.mask, reply: reply_tx })
        .map_err(|_| Error::Pipeline("device driver exited".into()))?;
    inflight.push_back((batch.rows, reply_rx));
    Ok(())
}

fn recv_batch(inflight: &mut Inflight) -> Result<(Vec<usize>, DeviceBatchOut)> {
    let (rows, rx) = inflight.pop_front().expect("drain on an empty in-flight queue");
    let out = rx
        .recv()
        .map_err(|_| Error::Pipeline("device driver dropped a batch".into()))??;
    Ok((rows, out))
}

/// Unpack one finished minwise batch: truncate to b bits straight into
/// each row's output slot, then recycle the slabs.
fn drain_one_bbit(
    inflight: &mut Inflight,
    k: usize,
    bmask: u32,
    codes: &mut [u16],
    free: &mut FreeSlabs,
) -> Result<()> {
    let (rows, batch) = recv_batch(inflight)?;
    let DeviceOut::Minhash(z) = batch.out else {
        return Err(Error::Pipeline("device driver returned the wrong output kind".into()));
    };
    for (slot, &row_id) in rows.iter().enumerate() {
        let src = &z[slot * k..(slot + 1) * k];
        let dst = &mut codes[row_id * k..(row_id + 1) * k];
        for (d, &v) in dst.iter_mut().zip(src) {
            *d = (v as u32 & bmask) as u16;
        }
    }
    free.push((batch.idx, batch.mask));
    Ok(())
}

/// Unpack one finished VW batch: sparsify each dense row (ascending bin,
/// exact zeros dropped — the same shape `hash_sparse_with` emits), then
/// recycle the slabs.
fn drain_one_vw(
    inflight: &mut Inflight,
    bins: usize,
    rows_out: &mut [(i8, Vec<(u32, f32)>)],
    free: &mut FreeSlabs,
) -> Result<()> {
    let (rows, batch) = recv_batch(inflight)?;
    let DeviceOut::Vw(v) = batch.out else {
        return Err(Error::Pipeline("device driver returned the wrong output kind".into()));
    };
    for (slot, &row_id) in rows.iter().enumerate() {
        let dense = &v[slot * bins..(slot + 1) * bins];
        let out = &mut rows_out[row_id].1;
        for (j, &val) in dense.iter().enumerate() {
            if val != 0.0 {
                out.push((j as u32, val));
            }
        }
    }
    free.push((batch.idx, batch.mask));
    Ok(())
}

/// The CPU twin of the device kernel — drawn with the identical sequence
/// `EncoderSpec::encoder()` uses, so per-row fallback output is
/// bit-identical to the device rows around it.
enum CpuTwin {
    Bbit(BbitMinHash),
    Vw(VwHasher),
    Other,
}

#[derive(Default)]
struct DeviceStats {
    chunks: AtomicU64,
    fallbacks: AtomicU64,
    nanos: AtomicU64,
}

/// An `xla`-backed [`FeatureEncoder`]: device-resident minwise/VW hashing
/// on the chunk encode path, CPU everywhere else (margins, signatures,
/// `Example` chunks), automatic CPU fallback when PJRT is unavailable.
/// See the module docs for the threading and parity story.
pub struct DeviceEncoder {
    spec: EncoderSpec,
    /// The full CPU encoder: whole-chunk fallback + the non-chunk trait
    /// surface (margin / signature / scratch).
    cpu: Box<dyn FeatureEncoder>,
    twin: CpuTwin,
    handle: Option<DeviceHandle>,
    stats: DeviceStats,
}

impl DeviceEncoder {
    /// Build a device-backed encoder for `spec` over `artifacts_dir`.
    /// Device unavailability is never an error: every fallback reason
    /// (missing artifacts dir, no matching artifact, unsupported scheme,
    /// compile failure) is logged to stderr and the encoder runs on the
    /// CPU; only an invalid `spec` itself fails.
    pub fn new(spec: &EncoderSpec, artifacts_dir: &Path) -> Result<Self> {
        let cpu = spec.encoder()?; // validates the spec
        let twin = match *spec {
            EncoderSpec::Bbit { b, k, d, seed } => {
                CpuTwin::Bbit(BbitMinHash::draw(k, b, d, &mut Rng::new(seed)))
            }
            EncoderSpec::Vw { bins, seed } => {
                CpuTwin::Vw(VwHasher::draw(bins, &mut Rng::new(seed)))
            }
            _ => CpuTwin::Other,
        };
        let handle = if matches!(twin, CpuTwin::Other) {
            eprintln!(
                "device encode unavailable (scheme {} has no device kernel); using CPU",
                spec.scheme()
            );
            None
        } else {
            match spawn_driver(spec, artifacts_dir) {
                Ok(h) => Some(h),
                Err(reason) => {
                    eprintln!("device encode unavailable ({reason}); using CPU");
                    None
                }
            }
        };
        Ok(DeviceEncoder { spec: *spec, cpu, twin, handle, stats: DeviceStats::default() })
    }

    /// Whether the device path is live (false = everything runs on CPU).
    pub fn device_active(&self) -> bool {
        self.handle.is_some()
    }

    /// The compiled `(batch, nnz)` launch geometry, when active.
    pub fn batch_geometry(&self) -> Option<(usize, usize)> {
        self.handle.as_ref().map(|h| (h.batch, h.nnz))
    }

    fn encode_bbit_device(
        &self,
        h: &DeviceHandle,
        hasher: &BbitMinHash,
        chunk: &ParsedChunk,
    ) -> Result<EncodedChunk> {
        let (b, k) = (hasher.b, hasher.k());
        let bmask = (1u32 << b) - 1;
        let n = chunk.len();
        let cap = h.batch * h.nnz;
        STAGING.with(|cell| {
            let mut st = cell.borrow_mut();
            let st = &mut *st;
            let tx = rearm(st, h)?;
            st.codes.clear();
            st.codes.resize(n * k, 0);
            st.z.clear();
            st.z.resize(k, 0);
            st.row.clear();
            st.row.resize(k, 0);
            let mut inflight: Inflight = VecDeque::new();
            let mut cur: Option<Batch> = None;
            for i in 0..n {
                let set = chunk.row(i).0;
                if set.len() > h.nnz || set.iter().any(|&t| t > i32::MAX as u32) {
                    // a row the compiled geometry cannot carry: CPU twin,
                    // straight into its slot (bit-exact, so order-safe)
                    hasher.codes_into(set, &mut st.z, &mut st.row);
                    st.codes[i * k..(i + 1) * k].copy_from_slice(&st.row);
                    continue;
                }
                let batch = cur.get_or_insert_with(|| Batch::acquire(&mut st.free, cap));
                batch.stage(h.nnz, i, set);
                if batch.rows.len() == h.batch {
                    submit(&tx, cur.take().unwrap(), &mut inflight)?;
                    // keep one executing + one staged: pad the next slab
                    // while the driver runs the previous launch
                    while inflight.len() >= 2 {
                        drain_one_bbit(&mut inflight, k, bmask, &mut st.codes, &mut st.free)?;
                    }
                }
            }
            if let Some(partial) = cur.take() {
                if partial.rows.is_empty() {
                    st.free.push((partial.idx, partial.mask));
                } else {
                    submit(&tx, partial, &mut inflight)?;
                }
            }
            while !inflight.is_empty() {
                drain_one_bbit(&mut inflight, k, bmask, &mut st.codes, &mut st.free)?;
            }
            let mut packed = PackedCodes::new(b, k);
            packed.reserve_rows(n);
            let mut labels = Vec::with_capacity(n);
            for i in 0..n {
                packed.push_row(&st.codes[i * k..(i + 1) * k])?;
                labels.push(chunk.label(i));
            }
            Ok(EncodedChunk::Packed { codes: packed, labels })
        })
    }

    fn encode_vw_device(
        &self,
        h: &DeviceHandle,
        hasher: &VwHasher,
        chunk: &ParsedChunk,
    ) -> Result<EncodedChunk> {
        let bins = hasher.bins;
        let n = chunk.len();
        let cap = h.batch * h.nnz;
        STAGING.with(|cell| {
            let mut st = cell.borrow_mut();
            let st = &mut *st;
            let tx = rearm(st, h)?;
            let mut rows_out: Vec<(i8, Vec<(u32, f32)>)> =
                (0..n).map(|i| (chunk.label(i), Vec::new())).collect();
            let mut inflight: Inflight = VecDeque::new();
            let mut cur: Option<Batch> = None;
            for i in 0..n {
                let set = chunk.row(i).0;
                if set.len() > h.nnz || set.iter().any(|&t| t > i32::MAX as u32) {
                    rows_out[i].1 = hasher.hash_sparse_with(set, &mut st.pairs);
                    continue;
                }
                let batch = cur.get_or_insert_with(|| Batch::acquire(&mut st.free, cap));
                batch.stage(h.nnz, i, set);
                if batch.rows.len() == h.batch {
                    submit(&tx, cur.take().unwrap(), &mut inflight)?;
                    while inflight.len() >= 2 {
                        drain_one_vw(&mut inflight, bins, &mut rows_out, &mut st.free)?;
                    }
                }
            }
            if let Some(partial) = cur.take() {
                if partial.rows.is_empty() {
                    st.free.push((partial.idx, partial.mask));
                } else {
                    submit(&tx, partial, &mut inflight)?;
                }
            }
            while !inflight.is_empty() {
                drain_one_vw(&mut inflight, bins, &mut rows_out, &mut st.free)?;
            }
            Ok(EncodedChunk::Sparse { rows: rows_out })
        })
    }
}

/// Refresh the calling thread's cached sender when the handle changed
/// (sequential encoders must not reuse each other's slabs or channel),
/// then hand out a clone for this chunk.
fn rearm(st: &mut Staging, h: &DeviceHandle) -> Result<SyncSender<DeviceJob>> {
    if st.handle_id != h.id {
        st.tx = h.tx.lock().unwrap().clone();
        st.handle_id = h.id;
        st.free.clear();
    }
    st.tx
        .clone()
        .ok_or_else(|| Error::Pipeline("device driver unavailable".into()))
}

fn spawn_driver(spec: &EncoderSpec, dir: &Path) -> std::result::Result<DeviceHandle, String> {
    // enough slack for every worker to keep its two batches in flight
    let depth = 2 * crate::config::available_workers().max(1);
    let (job_tx, job_rx) = mpsc::sync_channel::<DeviceJob>(depth);
    let (ready_tx, ready_rx) = mpsc::channel();
    let stop = Arc::new(AtomicBool::new(false));
    let driver = std::thread::Builder::new()
        .name("bbmh-device-driver".into())
        .spawn({
            let (spec, dir, stop) = (*spec, dir.to_path_buf(), stop.clone());
            move || run_driver(dir, spec, ready_tx, job_rx, stop)
        })
        .map_err(|e| format!("cannot spawn driver thread: {e}"))?;
    match ready_rx.recv() {
        Ok(Ok((batch, nnz))) => Ok(DeviceHandle {
            tx: Mutex::new(Some(job_tx)),
            driver: Mutex::new(Some(driver)),
            stop,
            batch,
            nnz,
            id: HANDLE_IDS.fetch_add(1, Ordering::Relaxed),
        }),
        Ok(Err(reason)) => {
            let _ = driver.join();
            Err(reason)
        }
        Err(_) => {
            let _ = driver.join();
            Err("driver thread died during initialization".into())
        }
    }
}

impl FeatureEncoder for DeviceEncoder {
    fn spec(&self) -> EncoderSpec {
        self.spec
    }

    fn encode_chunk(&self, chunk: &[Example]) -> Result<EncodedChunk> {
        // the Example path is off the ingest hot loop — CPU is fine
        self.cpu.encode_chunk(chunk)
    }

    fn encode_parsed(&self, chunk: &ParsedChunk) -> Result<EncodedChunk> {
        let Some(h) = &self.handle else {
            self.stats.fallbacks.fetch_add(1, Ordering::Relaxed);
            set_encode_used_device(false);
            return self.cpu.encode_parsed(chunk);
        };
        let t0 = Instant::now();
        let result = match &self.twin {
            CpuTwin::Bbit(hasher) => self.encode_bbit_device(h, hasher, chunk),
            CpuTwin::Vw(hasher) => self.encode_vw_device(h, hasher, chunk),
            CpuTwin::Other => unreachable!("a handle exists only for bbit/vw"),
        };
        match result {
            Ok(out) => {
                self.stats.chunks.fetch_add(1, Ordering::Relaxed);
                self.stats
                    .nanos
                    .fetch_add(t0.elapsed().as_nanos() as u64, Ordering::Relaxed);
                set_encode_used_device(true);
                Ok(out)
            }
            Err(e) => {
                eprintln!("device encode failed ({e}); CPU fallback for this chunk");
                self.stats.fallbacks.fetch_add(1, Ordering::Relaxed);
                set_encode_used_device(false);
                self.cpu.encode_parsed(chunk)
            }
        }
    }

    fn scratch(&self) -> EncodeScratch {
        self.cpu.scratch()
    }

    fn margin(&self, set: &[u32], w: &[f32], scratch: &mut EncodeScratch) -> f32 {
        self.cpu.margin(set, w, scratch)
    }

    fn signature_into(&self, set: &[u32], scratch: &mut EncodeScratch) -> bool {
        self.cpu.signature_into(set, scratch)
    }

    fn device_stats(&self) -> Option<DeviceStatsSnapshot> {
        Some(DeviceStatsSnapshot {
            device_chunks: self.stats.chunks.load(Ordering::Relaxed),
            device_fallbacks: self.stats.fallbacks.load(Ordering::Relaxed),
            device_seconds: self.stats.nanos.load(Ordering::Relaxed) as f64 / 1e9,
        })
    }
}
