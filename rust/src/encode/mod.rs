//! Hashed-data encodings.
//!
//! - [`packed`]: the paper's `n·b·k`-bit storage — b-bit codes bit-packed
//!   into words, the whole point of b-bit minwise hashing (Section 2/3).
//! - [`expansion`]: run-time expansion of a code row into the `2^b × k`
//!   binary vector fed to a linear solver (Section 3), in both explicit
//!   CSR form and the implicit offsets+codes form the solvers and the PJRT
//!   train artifacts consume.

pub mod expansion;
pub mod packed;

pub use packed::PackedCodes;
