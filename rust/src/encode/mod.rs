//! Hashed-data encodings.
//!
//! - [`encoder`]: the scheme-agnostic [`FeatureEncoder`] API —
//!   [`EncoderSpec`] (the serializable scheme description every layer
//!   persists) plus the trait implementations for b-bit minwise, VW,
//!   random projections and one-permutation hashing.
//! - [`packed`]: the paper's `n·b·k`-bit storage — b-bit codes bit-packed
//!   into words, the whole point of b-bit minwise hashing (Section 2/3).
//! - [`expansion`]: run-time expansion of a code row into the `2^b × k`
//!   binary vector fed to a linear solver (Section 3), in both explicit
//!   CSR form and the implicit offsets+codes form the solvers and the PJRT
//!   train artifacts consume.
//! - [`cache`]: the on-disk hashed-chunk cache (checksummed record stream)
//!   behind the "hash once, train many times" out-of-core workflow; its
//!   header stores the [`EncoderSpec`] the chunks were encoded with, and
//!   since v3 a chunk-index footer makes the file seekable for parallel
//!   replay (plus optional RLE record compression via [`codec`]).
//! - [`codec`]: the std-only varint+RLE payload compressor behind the
//!   cache's `--cache-compress` flag.
//! - [`device`]: the `--device xla` encoder — [`DeviceEncoder`] batches
//!   `ParsedChunk`s into the AOT PJRT minwise/VW kernels from the pipeline
//!   workers, bit-identical to the CPU path, with automatic CPU fallback
//!   when no PJRT stack is available.

pub mod cache;
pub mod codec;
pub mod device;
pub mod encoder;
pub mod expansion;
pub mod packed;

pub use cache::{
    CacheMeta, CacheReader, CacheWriteOptions, CacheWriter, ChunkIndex, ChunkIndexEntry,
    IndexedCacheReader,
};
pub use device::DeviceEncoder;
pub use encoder::{
    draw, DeviceStatsSnapshot, EncodeScratch, EncodedChunk, EncoderSpec, FeatureEncoder,
};
pub use packed::PackedCodes;
