//! The scheme-agnostic feature-encoder API.
//!
//! The paper's central comparison — b-bit minwise hashing vs. VW hashing
//! at equal storage — used to be wired through a closed two-variant enum
//! with scheme parameters re-duplicated in the pipeline workers, the cache
//! header, the model file and the CLI.  This module replaces all of that
//! with one seam:
//!
//! - [`EncoderSpec`] — a small, copyable, serializable *description* of an
//!   encoder (scheme tag + parameters + seed).  It is what cache headers
//!   and model files store, what the CLI parses, and what every layer
//!   passes around.
//! - [`FeatureEncoder`] — the trait the pipeline workers, the classify
//!   path and the experiments drive.  Implementations are drawn
//!   *deterministically* from a spec ([`draw`] / [`EncoderSpec::encoder`]),
//!   so persisting the spec is always enough to reconstruct the exact hash
//!   family (DESIGN.md §5b).
//! - [`EncodedChunk`] — the worker→sink currency: packed b-bit codes
//!   (b-bit minwise, OPH) or sparse hashed rows (VW, random projections).
//!
//! Adding a scheme means implementing the trait and adding a spec variant;
//! the pipeline, sinks, cache, model IO, CLI and experiments pick it up
//! without modification.  One-permutation hashing
//! ([`OphEncoder`]) is the proof: it landed without touching the
//! coordinator at all.

use crate::data::dataset::Example;
use crate::data::libsvm::ParsedChunk;
use crate::encode::packed::PackedCodes;
use crate::hashing::minwise::BbitMinHash;
use crate::hashing::oph::OnePermutationHasher;
use crate::hashing::rp::RandomProjection;
use crate::hashing::vw::VwHasher;
use crate::util::Rng;
use crate::{Error, Result};

/// Serializable description of a feature encoder: scheme + parameters +
/// the seed its hash family is drawn from.
///
/// This is the single source of truth every layer shares — the cache
/// header ([`header_fields`](Self::header_fields)), the model file
/// ([`SavedModel`](crate::solver::SavedModel)), and the CLI all persist
/// exactly this.
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum EncoderSpec {
    /// k-way minwise hashing over domain `[0, d)`, truncated to b bits and
    /// packed (the paper's method, Sections 2–3).
    Bbit { b: u32, k: usize, d: u64, seed: u64 },
    /// VW signed feature hashing into `bins` bins (Section 5).
    Vw { bins: usize, seed: u64 },
    /// Sparse random projections to `proj` dimensions with sparsity
    /// parameter `s` (Section 5.1, Eq. 11).
    Rp { proj: usize, s: f64, seed: u64 },
    /// One-permutation hashing: a single hash pass, `bins` partitions,
    /// b-bit codes (Li–Owen–Zhang 2012).
    Oph { bins: usize, b: u32, seed: u64 },
}

impl EncoderSpec {
    /// Short scheme tag as the CLI spells it (`--encoder <scheme>`).
    pub fn scheme(&self) -> &'static str {
        match self {
            EncoderSpec::Bbit { .. } => "bbit",
            EncoderSpec::Vw { .. } => "vw",
            EncoderSpec::Rp { .. } => "rp",
            EncoderSpec::Oph { .. } => "oph",
        }
    }

    /// The seed the encoder's hash family is drawn from.
    pub fn seed(&self) -> u64 {
        match *self {
            EncoderSpec::Bbit { seed, .. }
            | EncoderSpec::Vw { seed, .. }
            | EncoderSpec::Rp { seed, .. }
            | EncoderSpec::Oph { seed, .. } => seed,
        }
    }

    /// Dimensionality of the encoded feature space a solver trains
    /// against: `2^b·k` for packed-code schemes, the bin/projection count
    /// for sparse schemes.
    pub fn output_dim(&self) -> usize {
        match *self {
            EncoderSpec::Bbit { b, k, .. } => (1usize << b) * k,
            EncoderSpec::Vw { bins, .. } => bins,
            EncoderSpec::Rp { proj, .. } => proj,
            EncoderSpec::Oph { bins, b, .. } => (1usize << b) * bins,
        }
    }

    /// `(b, codes-per-row)` for schemes that emit packed b-bit codes
    /// (b-bit minwise, OPH) — the [`PackedCodes`] geometry the cache and
    /// the streaming trainer need; `None` for sparse-output schemes.
    pub fn packed_geometry(&self) -> Option<(u32, usize)> {
        match *self {
            EncoderSpec::Bbit { b, k, .. } => Some((b, k)),
            EncoderSpec::Oph { bins, b, .. } => Some((b, bins)),
            EncoderSpec::Vw { .. } | EncoderSpec::Rp { .. } => None,
        }
    }

    /// Parameter sanity (mirrors the asserts in the underlying hashers so
    /// bad CLI input surfaces as an error, not a panic).
    pub fn validate(&self) -> Result<()> {
        match *self {
            EncoderSpec::Bbit { b, k, d, .. } => {
                if !(1..=16).contains(&b) {
                    return Err(Error::InvalidArg(format!("b must be 1..=16, got {b}")));
                }
                if k == 0 {
                    return Err(Error::InvalidArg("k must be >= 1".into()));
                }
                if d == 0 {
                    return Err(Error::InvalidArg("d must be >= 1".into()));
                }
            }
            EncoderSpec::Vw { bins, .. } => {
                if bins == 0 {
                    return Err(Error::InvalidArg("bins must be >= 1".into()));
                }
            }
            EncoderSpec::Rp { proj, s, .. } => {
                if proj == 0 {
                    return Err(Error::InvalidArg("proj must be >= 1".into()));
                }
                if s < 1.0 || !s.is_finite() {
                    return Err(Error::InvalidArg(format!("s must be >= 1, got {s}")));
                }
            }
            EncoderSpec::Oph { bins, b, .. } => {
                if bins == 0 {
                    return Err(Error::InvalidArg("bins must be >= 1".into()));
                }
                if !(1..=16).contains(&b) {
                    return Err(Error::InvalidArg(format!("b must be 1..=16, got {b}")));
                }
            }
        }
        Ok(())
    }

    /// Draw this spec's encoder deterministically (a fresh
    /// `Rng::new(self.seed())` — the exact draw sequence every prior layer
    /// used, so packed output is byte-identical to the pre-trait code).
    pub fn encoder(&self) -> Result<Box<dyn FeatureEncoder>> {
        draw(self, &mut Rng::new(self.seed()))
    }

    /// Fixed-width header encoding shared by the v2 cache format
    /// (`encode/cache.rs` documents the byte layout):
    /// `(tag, p0: u32, p1: u64, p2: u64, seed)`.
    ///
    /// | scheme | tag | p0 | p1   | p2          |
    /// |--------|-----|----|------|-------------|
    /// | bbit   | 0   | b  | k    | d           |
    /// | vw     | 1   | 0  | bins | 0           |
    /// | rp     | 2   | 0  | proj | s.to_bits() |
    /// | oph    | 3   | b  | bins | 0           |
    pub fn header_fields(&self) -> (u32, u32, u64, u64, u64) {
        match *self {
            EncoderSpec::Bbit { b, k, d, seed } => (0, b, k as u64, d, seed),
            EncoderSpec::Vw { bins, seed } => (1, 0, bins as u64, 0, seed),
            EncoderSpec::Rp { proj, s, seed } => (2, 0, proj as u64, s.to_bits(), seed),
            EncoderSpec::Oph { bins, b, seed } => (3, b, bins as u64, 0, seed),
        }
    }

    /// Inverse of [`header_fields`](Self::header_fields); validates the
    /// reconstructed spec.
    pub fn from_header_fields(tag: u32, p0: u32, p1: u64, p2: u64, seed: u64) -> Result<Self> {
        let spec = match tag {
            0 => EncoderSpec::Bbit { b: p0, k: p1 as usize, d: p2, seed },
            1 => EncoderSpec::Vw { bins: p1 as usize, seed },
            2 => EncoderSpec::Rp { proj: p1 as usize, s: f64::from_bits(p2), seed },
            3 => EncoderSpec::Oph { bins: p1 as usize, b: p0, seed },
            other => {
                return Err(Error::InvalidArg(format!("unknown encoder scheme tag {other}")))
            }
        };
        spec.validate()?;
        Ok(spec)
    }

    /// Text encoding of the spec as `encoder <scheme>` + `key value`
    /// lines — the model-file header (`solver/model_io.rs`).  Kept beside
    /// [`header_fields`](Self::header_fields) so every serialization of a
    /// spec lives in this module; the inverse is
    /// [`read_text_fields`](Self::read_text_fields).
    pub fn write_text_fields<W: std::io::Write>(&self, w: &mut W) -> std::io::Result<()> {
        writeln!(w, "encoder {}", self.scheme())?;
        match *self {
            EncoderSpec::Bbit { b, k, d, seed } => {
                writeln!(w, "b {b}")?;
                writeln!(w, "k {k}")?;
                writeln!(w, "d {d}")?;
                writeln!(w, "seed {seed}")
            }
            EncoderSpec::Vw { bins, seed } => {
                writeln!(w, "bins {bins}")?;
                writeln!(w, "seed {seed}")
            }
            EncoderSpec::Rp { proj, s, seed } => {
                writeln!(w, "proj {proj}")?;
                // Display of f64 is the shortest round-tripping decimal
                writeln!(w, "s {s}")?;
                writeln!(w, "seed {seed}")
            }
            EncoderSpec::Oph { bins, b, seed } => {
                writeln!(w, "bins {bins}")?;
                writeln!(w, "b {b}")?;
                writeln!(w, "seed {seed}")
            }
        }
    }

    /// Inverse of [`write_text_fields`](Self::write_text_fields).
    /// `next_kv(key)` must return the value of the next `key value` line
    /// (erroring on a key mismatch); the caller owns line iteration so
    /// this works over any header framing.  Validates the result.
    pub fn read_text_fields(
        next_kv: &mut dyn FnMut(&str) -> Result<String>,
    ) -> Result<Self> {
        fn num<T: std::str::FromStr>(v: &str, key: &str) -> Result<T> {
            v.parse()
                .map_err(|_| Error::InvalidArg(format!("bad {key} value {v:?}")))
        }
        let spec = match next_kv("encoder")?.as_str() {
            "bbit" => EncoderSpec::Bbit {
                b: num(&next_kv("b")?, "b")?,
                k: num(&next_kv("k")?, "k")?,
                d: num(&next_kv("d")?, "d")?,
                seed: num(&next_kv("seed")?, "seed")?,
            },
            "vw" => EncoderSpec::Vw {
                bins: num(&next_kv("bins")?, "bins")?,
                seed: num(&next_kv("seed")?, "seed")?,
            },
            "rp" => EncoderSpec::Rp {
                proj: num(&next_kv("proj")?, "proj")?,
                s: num(&next_kv("s")?, "s")?,
                seed: num(&next_kv("seed")?, "seed")?,
            },
            "oph" => EncoderSpec::Oph {
                bins: num(&next_kv("bins")?, "bins")?,
                b: num(&next_kv("b")?, "b")?,
                seed: num(&next_kv("seed")?, "seed")?,
            },
            other => {
                return Err(Error::InvalidArg(format!("unknown encoder scheme {other:?}")))
            }
        };
        spec.validate()?;
        Ok(spec)
    }
}

/// One encoded chunk — the worker→sink currency of the pipeline.
pub enum EncodedChunk {
    /// Packed b-bit codes + labels for a run of consecutive input rows
    /// (b-bit minwise, OPH).
    Packed { codes: PackedCodes, labels: Vec<i8> },
    /// Sparse hashed rows as `(label, sorted (index, value) pairs)` (VW,
    /// random projections).
    Sparse { rows: Vec<(i8, Vec<(u32, f32)>)> },
}

impl EncodedChunk {
    pub fn len(&self) -> usize {
        match self {
            EncodedChunk::Packed { labels, .. } => labels.len(),
            EncodedChunk::Sparse { rows } => rows.len(),
        }
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }
}

/// Reusable per-thread buffers for single-document encoding (the classify
/// hot path): created via [`FeatureEncoder::scratch`], threaded through
/// [`FeatureEncoder::margin`].
#[derive(Default)]
pub struct EncodeScratch {
    /// Raw 64-bit hash values (minwise values / per-bin minima).
    pub z: Vec<u64>,
    /// b-bit codes.
    pub codes: Vec<u16>,
}

/// Point-in-time device-encode counters, surfaced through
/// [`FeatureEncoder::device_stats`] and folded into the
/// [`PipelineReport`](crate::coordinator::PipelineReport) after a run.
#[derive(Clone, Copy, Debug, Default, PartialEq)]
pub struct DeviceStatsSnapshot {
    /// Chunks encoded through the device path.
    pub device_chunks: u64,
    /// Chunks that fell back to the CPU kernels (mid-run device errors,
    /// or every chunk when the device was unavailable at construction).
    pub device_fallbacks: u64,
    /// Wall seconds spent inside device-path `encode_parsed` calls.
    pub device_seconds: f64,
}

thread_local! {
    /// Whether the current worker thread's most recent `encode_parsed`
    /// ran on the device — set by device-capable encoders, read-and-
    /// cleared by the pipeline worker to tag the `pipeline.encode` span's
    /// `device` field (so `--trace-out` separates device time from CPU
    /// encode time).
    static ENCODE_USED_DEVICE: std::cell::Cell<bool> = const { std::cell::Cell::new(false) };
}

/// Record whether the calling thread's last `encode_parsed` used the
/// device (device-capable encoders call this on every chunk).
pub fn set_encode_used_device(v: bool) {
    ENCODE_USED_DEVICE.with(|c| c.set(v));
}

/// Read-and-clear the calling thread's device flag
/// ([`set_encode_used_device`]); `false` when no device encoder ran.
pub fn take_encode_used_device() -> bool {
    ENCODE_USED_DEVICE.with(|c| c.replace(false))
}

/// A feature-encoding scheme the pipeline can run.
///
/// Implementations are immutable after [`draw`] and shared by reference
/// across the hash workers (`Send + Sync`); per-chunk state lives inside
/// `encode_chunk`, per-document state in [`EncodeScratch`].
pub trait FeatureEncoder: Send + Sync {
    /// The serializable description this encoder was drawn from.
    fn spec(&self) -> EncoderSpec;

    /// Encoded feature-space dimensionality (== `spec().output_dim()`).
    fn output_dim(&self) -> usize {
        self.spec().output_dim()
    }

    /// Encode one chunk of raw examples (the pipeline worker body).
    fn encode_chunk(&self, chunk: &[Example]) -> Result<EncodedChunk>;

    /// Encode one chunk of rows parsed by the byte-block ingest path —
    /// same output, row for row, as [`encode_chunk`](Self::encode_chunk)
    /// on the equivalent `Example`s.  The default materializes `Example`s
    /// (correct for any implementation); every built-in encoder overrides
    /// it with a row-view loop that allocates no per-document scratch, so
    /// parse → encode runs allocation-free end to end.
    fn encode_parsed(&self, chunk: &ParsedChunk) -> Result<EncodedChunk> {
        self.encode_chunk(&chunk.to_examples())
    }

    /// Fresh scratch sized for this encoder.
    fn scratch(&self) -> EncodeScratch {
        EncodeScratch::default()
    }

    /// Margin of one raw binary document (set of feature indices) against
    /// a weight vector of length [`output_dim`](Self::output_dim) — the
    /// classify request path, computed without materializing the encoded
    /// vector.
    fn margin(&self, set: &[u32], w: &[f32], scratch: &mut EncodeScratch) -> f32;

    /// Hash one raw binary document into its packed code signature,
    /// leaving the codes in `scratch.codes` (scratch from
    /// [`scratch`](Self::scratch)).  Returns `false` for sparse-output
    /// schemes (VW, random projections), which have no per-hash code row —
    /// the near-neighbor path ([`crate::similarity`]) uses this to hash
    /// `/similar` queries with the exact family the index was built from.
    fn signature_into(&self, set: &[u32], scratch: &mut EncodeScratch) -> bool {
        let _ = (set, scratch);
        false
    }

    /// Device-path counters for encoders that offload chunk encoding to
    /// an accelerator ([`crate::encode::device::DeviceEncoder`]); `None`
    /// for pure-CPU encoders.  The pipeline folds the snapshot into its
    /// report after a run.
    fn device_stats(&self) -> Option<DeviceStatsSnapshot> {
        None
    }
}

/// Draw the encoder a spec describes, consuming randomness from `rng`.
/// With `rng = Rng::new(spec.seed())` (what [`EncoderSpec::encoder`] does)
/// the drawn family is identical to what the pre-trait pipeline, cache and
/// model loader constructed.
pub fn draw(spec: &EncoderSpec, rng: &mut Rng) -> Result<Box<dyn FeatureEncoder>> {
    spec.validate()?;
    Ok(match *spec {
        EncoderSpec::Bbit { b, k, d, seed } => {
            Box::new(BbitEncoder { hasher: BbitMinHash::draw(k, b, d, rng), seed })
        }
        EncoderSpec::Vw { bins, seed } => {
            Box::new(VwEncoder { hasher: VwHasher::draw(bins, rng), seed })
        }
        EncoderSpec::Rp { proj, s, seed } => {
            Box::new(RpEncoder { proj: RandomProjection::new(proj, s, rng), seed })
        }
        EncoderSpec::Oph { bins, b, seed } => {
            Box::new(OphEncoder { hasher: OnePermutationHasher::draw(bins, b, rng), seed })
        }
    })
}

/// Encode `n` rows through any `codes_into(set, z_scratch, code_row)`
/// packed-code hasher — the shared core of the b-bit minwise and OPH
/// encoders for both the `Example` and the parsed-row ingest paths.  All
/// scratch (minwise values, one code row) is per-chunk; the per-document
/// loop allocates nothing.
fn packed_rows<'a>(
    b: u32,
    k: usize,
    n: usize,
    mut row_of: impl FnMut(usize) -> (&'a [u32], i8),
    mut codes_into: impl FnMut(&[u32], &mut [u64], &mut [u16]),
) -> Result<EncodedChunk> {
    let mut codes = PackedCodes::new(b, k);
    codes.reserve_rows(n);
    let mut labels = Vec::with_capacity(n);
    let mut scratch = vec![0u64; k];
    let mut row = vec![0u16; k];
    for i in 0..n {
        let (set, label) = row_of(i);
        codes_into(set, &mut scratch, &mut row);
        codes.push_row(&row)?;
        labels.push(label);
    }
    Ok(EncodedChunk::Packed { codes, labels })
}

/// [`packed_rows`] over an `Example` slice.
fn packed_chunk(
    b: u32,
    k: usize,
    chunk: &[Example],
    codes_into: impl FnMut(&[u32], &mut [u64], &mut [u16]),
) -> Result<EncodedChunk> {
    packed_rows(b, k, chunk.len(), |i| (chunk[i].indices.as_slice(), chunk[i].label), codes_into)
}

/// [`packed_rows`] over a [`ParsedChunk`] (the byte-block ingest path).
fn packed_parsed(
    b: u32,
    k: usize,
    chunk: &ParsedChunk,
    codes_into: impl FnMut(&[u32], &mut [u64], &mut [u16]),
) -> Result<EncodedChunk> {
    packed_rows(b, k, chunk.len(), |i| (chunk.row(i).0, chunk.label(i)), codes_into)
}

/// Expanded-space weight gather for one packed code row: the classify /
/// serve-scorer hot path every packed scheme shares (column j of code c
/// lives at `(j << b) + c`).  Delegates to the unrolled
/// multi-accumulator kernel — same lane structure as the trainer's dot,
/// so classify margins and trained-path margins stay bitwise consistent.
fn packed_margin(b: u32, codes: &[u16], w: &[f32]) -> f32 {
    crate::kernels::dot_codes(b, codes, w)
}

/// b-bit minwise hashing → packed codes (the paper's method).
pub struct BbitEncoder {
    hasher: BbitMinHash,
    seed: u64,
}

impl FeatureEncoder for BbitEncoder {
    fn spec(&self) -> EncoderSpec {
        EncoderSpec::Bbit {
            b: self.hasher.b,
            k: self.hasher.k(),
            d: self.hasher.hasher.d(),
            seed: self.seed,
        }
    }

    fn encode_chunk(&self, chunk: &[Example]) -> Result<EncodedChunk> {
        packed_chunk(self.hasher.b, self.hasher.k(), chunk, |set, z, row| {
            self.hasher.codes_into(set, z, row)
        })
    }

    fn encode_parsed(&self, chunk: &ParsedChunk) -> Result<EncodedChunk> {
        packed_parsed(self.hasher.b, self.hasher.k(), chunk, |set, z, row| {
            self.hasher.codes_into(set, z, row)
        })
    }

    fn scratch(&self) -> EncodeScratch {
        EncodeScratch { z: vec![0; self.hasher.k()], codes: vec![0; self.hasher.k()] }
    }

    fn margin(&self, set: &[u32], w: &[f32], scratch: &mut EncodeScratch) -> f32 {
        self.hasher.codes_into(set, &mut scratch.z, &mut scratch.codes);
        packed_margin(self.hasher.b, &scratch.codes, w)
    }

    fn signature_into(&self, set: &[u32], scratch: &mut EncodeScratch) -> bool {
        self.hasher.codes_into(set, &mut scratch.z, &mut scratch.codes);
        true
    }
}

/// VW signed feature hashing → sparse rows.
pub struct VwEncoder {
    hasher: VwHasher,
    seed: u64,
}

impl FeatureEncoder for VwEncoder {
    fn spec(&self) -> EncoderSpec {
        EncoderSpec::Vw { bins: self.hasher.bins, seed: self.seed }
    }

    fn encode_chunk(&self, chunk: &[Example]) -> Result<EncodedChunk> {
        let mut rows = Vec::with_capacity(chunk.len());
        let mut pairs = Vec::new();
        for ex in chunk {
            rows.push((ex.label, self.hasher.hash_sparse_with(&ex.indices, &mut pairs)));
        }
        Ok(EncodedChunk::Sparse { rows })
    }

    fn encode_parsed(&self, chunk: &ParsedChunk) -> Result<EncodedChunk> {
        // per-chunk pair scratch; the only per-row allocation left is the
        // output row the sparse chunk format owns
        let mut rows = Vec::with_capacity(chunk.len());
        let mut pairs = Vec::new();
        for (label, set, _) in chunk.rows() {
            rows.push((label, self.hasher.hash_sparse_with(set, &mut pairs)));
        }
        Ok(EncodedChunk::Sparse { rows })
    }

    fn margin(&self, set: &[u32], w: &[f32], _scratch: &mut EncodeScratch) -> f32 {
        // w·g with g the hashed vector: each t contributes sign(t)·w[bin(t)]
        set.iter().map(|&t| self.hasher.sign(t) * w[self.hasher.bin(t)]).sum()
    }
}

/// Sparse random projections → sparse rows (the zeros of the implicit
/// projection dropped).
pub struct RpEncoder {
    proj: RandomProjection,
    seed: u64,
}

impl FeatureEncoder for RpEncoder {
    fn spec(&self) -> EncoderSpec {
        EncoderSpec::Rp { proj: self.proj.k, s: self.proj.s, seed: self.seed }
    }

    fn encode_chunk(&self, chunk: &[Example]) -> Result<EncodedChunk> {
        let mut rows = Vec::with_capacity(chunk.len());
        let mut scratch = RpRowScratch::default();
        for ex in chunk {
            let pairs = self.project_row(&ex.indices, ex.values.as_deref(), &mut scratch);
            rows.push((ex.label, pairs));
        }
        Ok(EncodedChunk::Sparse { rows })
    }

    fn encode_parsed(&self, chunk: &ParsedChunk) -> Result<EncodedChunk> {
        let mut rows = Vec::with_capacity(chunk.len());
        let mut scratch = RpRowScratch::default();
        for (label, set, vals) in chunk.rows() {
            rows.push((label, self.project_row(set, vals, &mut scratch)));
        }
        Ok(EncodedChunk::Sparse { rows })
    }

    fn margin(&self, set: &[u32], w: &[f32], _scratch: &mut EncodeScratch) -> f32 {
        let v = self.proj.project_set(set);
        v.iter().zip(w).map(|(x, wi)| *x as f32 * wi).sum()
    }
}

/// Per-chunk buffers for the RP encoder's row loop: the dense projection
/// and the `(index, value)` pair list for valued rows.
#[derive(Default)]
struct RpRowScratch {
    dense: Vec<f64>,
    items: Vec<(u32, f32)>,
}

impl RpEncoder {
    /// Project one row and collect its nonzeros — scratch reused across
    /// rows, output `Vec` owned by the sparse chunk.
    fn project_row(
        &self,
        set: &[u32],
        vals: Option<&[f32]>,
        scratch: &mut RpRowScratch,
    ) -> Vec<(u32, f32)> {
        match vals {
            None => self.proj.project_set_into(set, &mut scratch.dense),
            Some(vals) => {
                scratch.items.clear();
                scratch
                    .items
                    .extend(set.iter().copied().zip(vals.iter().copied()));
                self.proj.project_into(&scratch.items, &mut scratch.dense);
            }
        }
        scratch
            .dense
            .iter()
            .enumerate()
            .filter(|(_, x)| **x != 0.0)
            .map(|(j, x)| (j as u32, *x as f32))
            .collect()
    }
}

/// One-permutation hashing → packed codes with k = `bins`.
pub struct OphEncoder {
    hasher: OnePermutationHasher,
    seed: u64,
}

impl FeatureEncoder for OphEncoder {
    fn spec(&self) -> EncoderSpec {
        EncoderSpec::Oph { bins: self.hasher.bins, b: self.hasher.b, seed: self.seed }
    }

    fn encode_chunk(&self, chunk: &[Example]) -> Result<EncodedChunk> {
        packed_chunk(self.hasher.b, self.hasher.bins, chunk, |set, mins, row| {
            self.hasher.codes_into(set, mins, row)
        })
    }

    fn encode_parsed(&self, chunk: &ParsedChunk) -> Result<EncodedChunk> {
        packed_parsed(self.hasher.b, self.hasher.bins, chunk, |set, mins, row| {
            self.hasher.codes_into(set, mins, row)
        })
    }

    fn scratch(&self) -> EncodeScratch {
        EncodeScratch { z: vec![0; self.hasher.bins], codes: vec![0; self.hasher.bins] }
    }

    fn margin(&self, set: &[u32], w: &[f32], scratch: &mut EncodeScratch) -> f32 {
        self.hasher.codes_into(set, &mut scratch.z, &mut scratch.codes);
        packed_margin(self.hasher.b, &scratch.codes, w)
    }

    fn signature_into(&self, set: &[u32], scratch: &mut EncodeScratch) -> bool {
        self.hasher.codes_into(set, &mut scratch.z, &mut scratch.codes);
        true
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn all_specs() -> Vec<EncoderSpec> {
        vec![
            EncoderSpec::Bbit { b: 8, k: 32, d: 1 << 24, seed: 5 },
            EncoderSpec::Vw { bins: 128, seed: 7 },
            EncoderSpec::Rp { proj: 64, s: 3.0, seed: 11 },
            EncoderSpec::Oph { bins: 96, b: 4, seed: 13 },
        ]
    }

    #[test]
    fn spec_encoder_spec_roundtrip() {
        for spec in all_specs() {
            let enc = spec.encoder().unwrap();
            assert_eq!(enc.spec(), spec, "{}", spec.scheme());
            assert_eq!(enc.output_dim(), spec.output_dim());
        }
    }

    #[test]
    fn header_fields_roundtrip() {
        for spec in all_specs() {
            let (tag, p0, p1, p2, seed) = spec.header_fields();
            let back = EncoderSpec::from_header_fields(tag, p0, p1, p2, seed).unwrap();
            assert_eq!(back, spec, "{}", spec.scheme());
        }
        assert!(EncoderSpec::from_header_fields(9, 0, 1, 0, 0).is_err());
    }

    #[test]
    fn validate_rejects_bad_parameters() {
        assert!(EncoderSpec::Bbit { b: 0, k: 8, d: 16, seed: 0 }.validate().is_err());
        assert!(EncoderSpec::Bbit { b: 17, k: 8, d: 16, seed: 0 }.validate().is_err());
        assert!(EncoderSpec::Bbit { b: 8, k: 0, d: 16, seed: 0 }.validate().is_err());
        assert!(EncoderSpec::Vw { bins: 0, seed: 0 }.validate().is_err());
        assert!(EncoderSpec::Rp { proj: 4, s: 0.5, seed: 0 }.validate().is_err());
        assert!(EncoderSpec::Rp { proj: 4, s: f64::NAN, seed: 0 }.validate().is_err());
        assert!(EncoderSpec::Oph { bins: 0, b: 4, seed: 0 }.validate().is_err());
        assert!(EncoderSpec::Oph { bins: 4, b: 0, seed: 0 }.validate().is_err());
    }

    #[test]
    fn packed_geometry_selects_packed_schemes() {
        assert_eq!(
            EncoderSpec::Bbit { b: 8, k: 32, d: 16, seed: 0 }.packed_geometry(),
            Some((8, 32))
        );
        assert_eq!(
            EncoderSpec::Oph { bins: 20, b: 4, seed: 0 }.packed_geometry(),
            Some((4, 20))
        );
        assert_eq!(EncoderSpec::Vw { bins: 8, seed: 0 }.packed_geometry(), None);
        assert_eq!(EncoderSpec::Rp { proj: 8, s: 1.0, seed: 0 }.packed_geometry(), None);
    }

    #[test]
    fn bbit_encoder_matches_direct_hasher_bit_for_bit() {
        // the trait path must reproduce the legacy pipeline worker exactly
        let spec = EncoderSpec::Bbit { b: 8, k: 16, d: 1 << 20, seed: 42 };
        let enc = spec.encoder().unwrap();
        let legacy = BbitMinHash::draw(16, 8, 1 << 20, &mut Rng::new(42));
        let mut rng = Rng::new(1);
        let exs: Vec<Example> = (0..10)
            .map(|_| {
                Example::binary(
                    1,
                    rng.sample_distinct(1 << 20, 30).into_iter().map(|x| x as u32).collect(),
                )
            })
            .collect();
        match enc.encode_chunk(&exs).unwrap() {
            EncodedChunk::Packed { codes, .. } => {
                for (i, ex) in exs.iter().enumerate() {
                    assert_eq!(codes.row(i), legacy.codes(&ex.indices), "row {i}");
                }
            }
            _ => panic!("bbit must emit packed chunks"),
        }
    }

    #[test]
    fn vw_encoder_matches_direct_hasher() {
        let spec = EncoderSpec::Vw { bins: 64, seed: 9 };
        let enc = spec.encoder().unwrap();
        let legacy = VwHasher::draw(64, &mut Rng::new(9));
        let ex = Example::binary(1, (0..200u32).map(|t| t * 13 % 4096).collect());
        match enc.encode_chunk(std::slice::from_ref(&ex)).unwrap() {
            EncodedChunk::Sparse { rows } => {
                assert_eq!(rows[0].1, legacy.hash_sparse(&ex.indices));
            }
            _ => panic!("vw must emit sparse chunks"),
        }
    }

    #[test]
    fn margin_matches_materialized_dot_per_scheme() {
        let mut wrng = Rng::new(77);
        let set: Vec<u32> = {
            let mut rng = Rng::new(3);
            rng.sample_distinct(1 << 20, 50).into_iter().map(|x| x as u32).collect()
        };
        let ex = Example::binary(1, set.clone());
        for spec in all_specs() {
            let enc = spec.encoder().unwrap();
            let w: Vec<f32> =
                (0..enc.output_dim()).map(|_| wrng.next_u64() as f32 / u64::MAX as f32).collect();
            let mut scratch = enc.scratch();
            let m = enc.margin(&ex.indices, &w, &mut scratch);
            // materialize via encode_chunk and dot by hand
            let dot = match enc.encode_chunk(std::slice::from_ref(&ex)).unwrap() {
                EncodedChunk::Packed { codes, .. } => {
                    let b = codes.b as usize;
                    (0..codes.k)
                        .map(|j| w[(j << b) + codes.get(0, j) as usize])
                        .sum::<f32>()
                }
                EncodedChunk::Sparse { rows } => {
                    rows[0].1.iter().map(|&(j, v)| v * w[j as usize]).sum::<f32>()
                }
            };
            let tol = 1e-3 * (1.0 + dot.abs());
            assert!((m - dot).abs() < tol, "{}: margin {m} dot {dot}", spec.scheme());
        }
    }

    #[test]
    fn encode_parsed_matches_encode_chunk_for_every_scheme() {
        // the byte-block worker path must emit the identical chunk, row
        // for row, as the Example path — valued, binary and unsorted rows
        let text = "+1 9:1 1:1 5:1\n-1 2:0.5 7:2\n+1 3:1 4:1 3:1\n0 1:1\n";
        let mut parsed = ParsedChunk::default();
        crate::data::libsvm::parse_block(text.as_bytes(), 1, false, &mut parsed).unwrap();
        let examples = parsed.to_examples();
        for spec in all_specs() {
            let enc = spec.encoder().unwrap();
            let a = enc.encode_chunk(&examples).unwrap();
            let b = enc.encode_parsed(&parsed).unwrap();
            match (a, b) {
                (
                    EncodedChunk::Packed { codes: ca, labels: la },
                    EncodedChunk::Packed { codes: cb, labels: lb },
                ) => {
                    assert_eq!(ca, cb, "{}", spec.scheme());
                    assert_eq!(la, lb, "{}", spec.scheme());
                }
                (
                    EncodedChunk::Sparse { rows: ra },
                    EncodedChunk::Sparse { rows: rb },
                ) => assert_eq!(ra, rb, "{}", spec.scheme()),
                _ => panic!("{}: chunk kinds diverged", spec.scheme()),
            }
        }
    }

    #[test]
    fn signature_into_matches_encode_chunk_row() {
        // the /similar query path must hash with the identical family the
        // cached/indexed rows came from: signature_into == encode_chunk row
        let set: Vec<u32> = {
            let mut rng = Rng::new(19);
            rng.sample_distinct(1 << 20, 40).into_iter().map(|x| x as u32).collect()
        };
        let ex = Example::binary(1, set.clone());
        for spec in all_specs() {
            let enc = spec.encoder().unwrap();
            let mut scratch = enc.scratch();
            let packed = enc.signature_into(&set, &mut scratch);
            match enc.encode_chunk(std::slice::from_ref(&ex)).unwrap() {
                EncodedChunk::Packed { codes, .. } => {
                    assert!(packed, "{}: packed scheme must emit a signature", spec.scheme());
                    assert_eq!(scratch.codes, codes.row(0), "{}", spec.scheme());
                }
                EncodedChunk::Sparse { .. } => {
                    assert!(!packed, "{}: sparse scheme has no signature", spec.scheme());
                }
            }
        }
    }

    #[test]
    fn oph_encoder_is_deterministic_across_draws() {
        let spec = EncoderSpec::Oph { bins: 32, b: 8, seed: 21 };
        let ex = Example::binary(-1, (0..100u32).map(|t| t * 7).collect());
        let c1 = match spec.encoder().unwrap().encode_chunk(std::slice::from_ref(&ex)).unwrap() {
            EncodedChunk::Packed { codes, .. } => codes,
            _ => panic!("oph must emit packed chunks"),
        };
        let c2 = match spec.encoder().unwrap().encode_chunk(std::slice::from_ref(&ex)).unwrap() {
            EncodedChunk::Packed { codes, .. } => codes,
            _ => unreachable!(),
        };
        assert_eq!(c1, c2);
        assert_eq!(c1.k, 32);
        assert_eq!(c1.b, 8);
    }
}
