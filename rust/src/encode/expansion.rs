//! Run-time expansion of b-bit codes into the 2^b × k representation
//! (paper Section 3).
//!
//! A code row (c_1, .., c_k) expands to a binary vector of length 2^b·k
//! with exactly k ones at columns `j·2^b + c_j`.  Two consumers:
//!
//! - the native solvers use the *implicit* form — a [`BbitDataset`] that
//!   yields expansion columns per row without materializing anything;
//! - `to_sparse_dataset` materializes explicit CSR for feeding any
//!   off-the-shelf solver (the paper feeds LIBLINEAR exactly this way) and
//!   for the LibSVM export path.

use crate::data::dataset::{Example, SparseDataset};
use crate::encode::packed::PackedCodes;

/// A b-bit hashed dataset in implicit expanded form.
#[derive(Clone, Debug)]
pub struct BbitDataset {
    pub codes: PackedCodes,
    pub labels: Vec<i8>,
}

impl BbitDataset {
    pub fn new(codes: PackedCodes, labels: Vec<i8>) -> Self {
        assert_eq!(codes.n, labels.len());
        BbitDataset { codes, labels }
    }

    pub fn len(&self) -> usize {
        self.labels.len()
    }

    pub fn is_empty(&self) -> bool {
        self.labels.is_empty()
    }

    /// Expanded dimensionality 2^b · k.
    pub fn dim(&self) -> usize {
        (1usize << self.codes.b) * self.codes.k
    }

    /// Expansion columns of row `i` into `out` (length k, strictly
    /// increasing — column j lives in block j).
    pub fn cols_into(&self, i: usize, out: &mut [u32]) {
        let b = self.codes.b as usize;
        for (j, o) in out.iter_mut().enumerate() {
            *o = ((j << b) + self.codes.get(i, j) as usize) as u32;
        }
    }

    pub fn cols(&self, i: usize) -> Vec<u32> {
        let mut out = vec![0; self.codes.k];
        self.cols_into(i, &mut out);
        out
    }

    /// Materialize explicit CSR (what the paper feeds to LIBLINEAR).
    pub fn to_sparse_dataset(&self) -> SparseDataset {
        let mut ds = SparseDataset::new(self.dim() as u64);
        let mut cols = vec![0u32; self.codes.k];
        for i in 0..self.len() {
            self.cols_into(i, &mut cols);
            ds.push(&Example { label: self.labels[i], indices: cols.clone(), values: None });
        }
        ds
    }

    /// Unpacked i32 code matrix rows [i0, i0+rows) in row-major order —
    /// the input layout of the PJRT train/predict artifacts.
    pub fn codes_i32(&self, i0: usize, rows: usize) -> Vec<i32> {
        let mut out = Vec::with_capacity(rows * self.codes.k);
        for i in i0..(i0 + rows).min(self.len()) {
            for j in 0..self.codes.k {
                out.push(self.codes.get(i, j) as i32);
            }
        }
        // pad with row 0-codes to the requested size (callers mask by count)
        out.resize(rows * self.codes.k, 0);
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::Rng;

    fn toy(b: u32, k: usize, n: usize, seed: u64) -> BbitDataset {
        let mut rng = Rng::new(seed);
        let mut pc = PackedCodes::new(b, k);
        let mut labels = Vec::new();
        for _ in 0..n {
            let row: Vec<u16> = (0..k).map(|_| rng.below(1 << b) as u16).collect();
            pc.push_row(&row).unwrap();
            labels.push(if rng.bool() { 1 } else { -1 });
        }
        BbitDataset::new(pc, labels)
    }

    #[test]
    fn cols_land_in_their_blocks() {
        let ds = toy(8, 20, 10, 1);
        for i in 0..ds.len() {
            let cols = ds.cols(i);
            assert_eq!(cols.len(), 20);
            for (j, &c) in cols.iter().enumerate() {
                let block = (c as usize) >> 8;
                assert_eq!(block, j);
                assert_eq!((c as usize) & 0xFF, ds.codes.get(i, j) as usize);
            }
            assert!(cols.windows(2).all(|w| w[0] < w[1]));
        }
    }

    #[test]
    fn csr_matches_implicit() {
        let ds = toy(4, 7, 25, 2);
        let csr = ds.to_sparse_dataset();
        csr.validate().unwrap();
        assert_eq!(csr.dim, 16 * 7);
        for i in 0..ds.len() {
            assert_eq!(csr.row(i).0, &ds.cols(i)[..]);
            assert_eq!(csr.labels[i], ds.labels[i]);
            assert_eq!(csr.nnz(i), 7); // exactly k ones
        }
    }

    #[test]
    fn codes_i32_layout() {
        let ds = toy(8, 5, 4, 3);
        let m = ds.codes_i32(1, 2);
        assert_eq!(m.len(), 10);
        for j in 0..5 {
            assert_eq!(m[j], ds.codes.get(1, j) as i32);
            assert_eq!(m[5 + j], ds.codes.get(2, j) as i32);
        }
        // padding beyond the end is zero
        let padded = ds.codes_i32(3, 4);
        assert!(padded[5..].iter().all(|&v| v == 0));
    }
}
