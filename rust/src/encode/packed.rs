//! Bit-packed b-bit code matrix: n rows × k codes × b bits each.
//!
//! This is the on-disk / in-memory format whose size — `n·b·k` bits — is
//! the storage the paper trades against VW's `k` 16/32-bit bins
//! (Section 5.3).  Codes are packed little-endian into u64 words with rows
//! padded to a word boundary so rows can be accessed independently (and
//! sharded workers can write disjoint row ranges without synchronization).

use crate::{Error, Result};

/// Packed b-bit codes.
#[derive(Clone, Debug, PartialEq)]
pub struct PackedCodes {
    /// Bits per code (1..=16).
    pub b: u32,
    /// Codes per row (the paper's k).
    pub k: usize,
    /// Number of rows.
    pub n: usize,
    /// Words per row (row stride).
    words_per_row: usize,
    data: Vec<u64>,
}

impl PackedCodes {
    pub fn new(b: u32, k: usize) -> Self {
        assert!((1..=16).contains(&b), "b must be 1..=16");
        let words_per_row = (k * b as usize).div_ceil(64);
        PackedCodes { b, k, n: 0, words_per_row, data: Vec::new() }
    }

    /// Pre-allocate `n` zeroed rows (for parallel writers).
    pub fn zeroed(b: u32, k: usize, n: usize) -> Self {
        let mut pc = PackedCodes::new(b, k);
        pc.n = n;
        pc.data = vec![0; pc.words_per_row * n];
        pc
    }

    /// Storage in bytes actually allocated.
    pub fn storage_bytes(&self) -> usize {
        self.data.len() * 8
    }

    /// Words per row (the row stride) — the unit [`words`](Self::words) is
    /// laid out in.
    pub fn stride(&self) -> usize {
        self.words_per_row
    }

    /// The raw packed words (row-major, `stride()` words per row) — the
    /// exact payload the on-disk cache records serialize.
    pub fn words(&self) -> &[u64] {
        &self.data
    }

    /// Rebuild from raw packed words (inverse of [`words`](Self::words));
    /// `data.len()` must equal `stride · n` for the (b, k) geometry.
    pub fn from_words(b: u32, k: usize, n: usize, data: Vec<u64>) -> Result<Self> {
        if !(1..=16).contains(&b) {
            return Err(Error::InvalidArg(format!("b must be 1..=16, got {b}")));
        }
        let words_per_row = (k * b as usize).div_ceil(64);
        if data.len() != words_per_row * n {
            return Err(Error::InvalidArg(format!(
                "packed payload has {} words, expected {} ({} rows × stride {})",
                data.len(),
                words_per_row * n,
                n,
                words_per_row
            )));
        }
        Ok(PackedCodes { b, k, n, words_per_row, data })
    }

    /// Drop all rows, keeping the (b, k) geometry and the allocation — the
    /// streaming trainer reuses one buffer per minibatch.
    pub fn clear(&mut self) {
        self.n = 0;
        self.data.clear();
    }

    /// Grow the underlying allocation to hold at least `rows` more rows
    /// (readers that know the total row count up front pre-size once
    /// instead of doubling their way up).
    pub fn reserve_rows(&mut self, rows: usize) {
        self.data.reserve(rows * self.words_per_row);
    }

    /// Replace all rows with `n` rows decoded from little-endian word
    /// bytes (the cache record payload layout), keeping the (b, k)
    /// geometry and reusing the allocation — the scratch-buffer twin of
    /// [`from_words`](Self::from_words) for the replay hot path.
    pub fn fill_from_le_bytes(&mut self, n: usize, bytes: &[u8]) -> Result<()> {
        let words = self.words_per_row * n;
        if bytes.len() != 8 * words {
            return Err(Error::InvalidArg(format!(
                "packed payload has {} bytes, expected {} ({} rows × stride {})",
                bytes.len(),
                8 * words,
                n,
                self.words_per_row
            )));
        }
        self.data.clear();
        self.data.reserve(words);
        self.data
            .extend(bytes.chunks_exact(8).map(|c| u64::from_le_bytes(c.try_into().unwrap())));
        self.n = n;
        Ok(())
    }

    /// The paper's idealized storage: exactly n·b·k bits, in bytes.
    pub fn ideal_bytes(&self) -> u64 {
        (self.n as u64 * self.b as u64 * self.k as u64).div_ceil(8)
    }

    /// Append one row of codes (each `< 2^b`).
    pub fn push_row(&mut self, codes: &[u16]) -> Result<()> {
        if codes.len() != self.k {
            return Err(Error::InvalidArg(format!(
                "row has {} codes, expected k={}",
                codes.len(),
                self.k
            )));
        }
        let limit = 1u32 << self.b;
        let row = self.n;
        self.data.resize(self.data.len() + self.words_per_row, 0);
        self.n += 1;
        for (j, &c) in codes.iter().enumerate() {
            if (c as u32) >= limit {
                self.n -= 1;
                self.data.truncate(self.data.len() - self.words_per_row);
                return Err(Error::InvalidArg(format!(
                    "code {c} out of range for b={}",
                    self.b
                )));
            }
            self.set(row, j, c);
        }
        Ok(())
    }

    /// Write code (row, j) — rows must already exist (`zeroed` or pushed).
    #[inline]
    pub fn set(&mut self, row: usize, j: usize, code: u16) {
        debug_assert!(row < self.n && j < self.k);
        debug_assert!((code as u32) < (1 << self.b));
        let bit = j * self.b as usize;
        let word = row * self.words_per_row + bit / 64;
        let off = bit % 64;
        let mask = ((1u64 << self.b) - 1) << off;
        self.data[word] = (self.data[word] & !mask) | ((code as u64) << off);
        let spill = off + self.b as usize;
        if spill > 64 {
            let hi_bits = spill - 64;
            let hi_mask = (1u64 << hi_bits) - 1;
            let hi = (code as u64) >> (self.b as usize - hi_bits);
            self.data[word + 1] = (self.data[word + 1] & !hi_mask) | hi;
        }
    }

    /// Read code (row, j).
    #[inline]
    pub fn get(&self, row: usize, j: usize) -> u16 {
        debug_assert!(row < self.n && j < self.k);
        let bit = j * self.b as usize;
        let word = row * self.words_per_row + bit / 64;
        let off = bit % 64;
        let mut v = self.data[word] >> off;
        let spill = off + self.b as usize;
        if spill > 64 {
            v |= self.data[word + 1] << (64 - off);
        }
        (v & ((1u64 << self.b) - 1)) as u16
    }

    /// Unpack one row into `out` (length k).
    pub fn row_into(&self, row: usize, out: &mut [u16]) {
        debug_assert_eq!(out.len(), self.k);
        for (j, o) in out.iter_mut().enumerate() {
            *o = self.get(row, j);
        }
    }

    /// Allocating convenience form of [`row_into`](Self::row_into) —
    /// tests and one-shot inspection only; hot paths use `row_into` or
    /// [`row_indices_into`](Self::row_indices_into).
    pub fn row(&self, row: usize) -> Vec<u16> {
        let mut out = vec![0; self.k];
        self.row_into(row, &mut out);
        out
    }

    /// Decode one whole row straight into expanded **gather indices**:
    /// `out[j] = (j << b) | code_j`, i.e. the weight-vector offsets of the
    /// implicit 2^b·k one-hot expansion (Section 3).  This is the
    /// train/score hot path: branchless, word-at-a-time, specialized per
    /// `b` — no per-element [`get`](Self::get).
    ///
    /// For b ∈ {1, 2, 4, 8, 16} codes never straddle a word (64 % b == 0)
    /// and a const-generic inner loop shifts codes out of each word; other
    /// b use a branch-free two-word blend.  Both produce the exact same
    /// indices as [`row_indices_scalar_into`](Self::row_indices_scalar_into)
    /// (pinned by tests in `tests/simd_kernels.rs` and below).
    ///
    /// `out.len()` must equal `k`.
    pub fn row_indices_into(&self, row: usize, out: &mut [u32]) {
        debug_assert!(row < self.n);
        debug_assert_eq!(out.len(), self.k);
        // (j << b) | code must fit a u32 for every j < k
        debug_assert!((self.k as u64) << self.b <= 1 << 32);
        if out.is_empty() {
            return;
        }
        let words = &self.data[row * self.words_per_row..(row + 1) * self.words_per_row];
        match self.b {
            1 => decode_pow2::<1>(words, out),
            2 => decode_pow2::<2>(words, out),
            4 => decode_pow2::<4>(words, out),
            8 => decode_pow2::<8>(words, out),
            16 => decode_pow2::<16>(words, out),
            b => decode_generic(words, b as usize, out),
        }
    }

    /// Reference implementation of [`row_indices_into`](Self::row_indices_into)
    /// built on per-element [`get`](Self::get) — the scalar kernel the
    /// parity tests (and the `bbmh_force_scalar` fallback) compare against.
    pub fn row_indices_scalar_into(&self, row: usize, out: &mut [u32]) {
        debug_assert_eq!(out.len(), self.k);
        let b = self.b as usize;
        for (j, o) in out.iter_mut().enumerate() {
            *o = ((j << b) + self.get(row, j) as usize) as u32;
        }
    }

    /// Merge rows from `other` (same b, k) after this one's rows — used by
    /// the pipeline collector to splice shard outputs.
    pub fn extend(&mut self, other: &PackedCodes) -> Result<()> {
        if self.b != other.b || self.k != other.k {
            return Err(Error::InvalidArg("packed geometry mismatch".into()));
        }
        self.data.extend_from_slice(&other.data);
        self.n += other.n;
        Ok(())
    }

    /// Copy a whole row from `other` at `src` into `self` at `dst`
    /// (geometries must match; rows are word-aligned so this is a memcpy).
    pub fn copy_row_from(&mut self, dst: usize, other: &PackedCodes, src: usize) {
        debug_assert_eq!(self.words_per_row, other.words_per_row);
        let (a, b) = (dst * self.words_per_row, src * other.words_per_row);
        self.data[a..a + self.words_per_row]
            .copy_from_slice(&other.data[b..b + other.words_per_row]);
    }

    /// Serialize to a writer: magic, geometry header, then little-endian
    /// words.  This is the "hashed dataset on disk" the paper re-uses
    /// across C-sweeps and experiments.
    pub fn save<W: std::io::Write>(&self, mut w: W) -> Result<()> {
        w.write_all(b"BBMH")?;
        for v in [self.b as u64, self.k as u64, self.n as u64] {
            w.write_all(&v.to_le_bytes())?;
        }
        for word in &self.data {
            w.write_all(&word.to_le_bytes())?;
        }
        Ok(())
    }

    /// Deserialize from a reader (counterpart of [`save`]).
    pub fn load<R: std::io::Read>(mut r: R) -> Result<Self> {
        let mut magic = [0u8; 4];
        r.read_exact(&mut magic)?;
        if &magic != b"BBMH" {
            return Err(Error::InvalidArg("bad packed-codes magic".into()));
        }
        let mut buf = [0u8; 8];
        let mut next = || -> Result<u64> {
            r.read_exact(&mut buf)?;
            Ok(u64::from_le_bytes(buf))
        };
        let (b, k, n) = (next()? as u32, next()? as usize, next()? as usize);
        if !(1..=16).contains(&b) {
            return Err(Error::InvalidArg(format!("bad b={b} in header")));
        }
        let mut pc = PackedCodes::zeroed(b, k, n);
        let mut bytes = vec![0u8; pc.data.len() * 8];
        r.read_exact(&mut bytes)?;
        for (i, chunk) in bytes.chunks_exact(8).enumerate() {
            pc.data[i] = u64::from_le_bytes(chunk.try_into().unwrap());
        }
        Ok(pc)
    }

    /// Re-truncate to fewer bits: from stored b-bit codes derive b'-bit
    /// codes (b' ≤ b) by masking — the paper's "store 16 bits once, use
    /// any b ≤ 16" trick the experiment harness exploits.
    pub fn truncate_bits(&self, b_new: u32) -> Result<PackedCodes> {
        if b_new > self.b {
            return Err(Error::InvalidArg(format!(
                "cannot widen {} -> {} bits",
                self.b, b_new
            )));
        }
        let mut out = PackedCodes::zeroed(b_new, self.k, self.n);
        // u32 intermediate: (1u16 << 16) would wrap for b_new == 16
        let mask = ((1u32 << b_new) - 1) as u16;
        for i in 0..self.n {
            for j in 0..self.k {
                out.set(i, j, self.get(i, j) & mask);
            }
        }
        Ok(out)
    }

    /// Keep only the first `k_new ≤ k` hash columns — lets one k=500 hash
    /// pass serve every smaller k in a sweep (minwise hashes are
    /// independent, so a prefix is a valid smaller family).
    pub fn truncate_k(&self, k_new: usize) -> Result<PackedCodes> {
        if k_new > self.k {
            return Err(Error::InvalidArg(format!(
                "cannot widen k {} -> {}",
                self.k, k_new
            )));
        }
        let mut out = PackedCodes::zeroed(self.b, k_new, self.n);
        for i in 0..self.n {
            for j in 0..k_new {
                out.set(i, j, self.get(i, j));
            }
        }
        Ok(out)
    }
}

/// Row decode for b dividing 64: each u64 holds exactly `64 / B` codes,
/// shifted out low-to-high.  Monomorphized per B so the shift amount and
/// per-word trip count are compile-time constants.
#[inline(always)]
fn decode_pow2<const B: u32>(words: &[u64], out: &mut [u32]) {
    let per = (64 / B) as usize;
    let mask = (1u64 << B) - 1;
    let k = out.len();
    for (wi, &w) in words.iter().enumerate() {
        let base = wi * per;
        let end = (base + per).min(k);
        let mut v = w;
        for (jj, o) in out[base..end].iter_mut().enumerate() {
            *o = (((base + jj) as u32) << B) | (v & mask) as u32;
            v >>= B;
        }
    }
}

/// Row decode for b not dividing 64 (codes may straddle two words):
/// branch-free two-word blend per code.  `(x << 1) << (63 - off)` is
/// `x << (64 - off)` without the off == 0 shift-by-64 UB; the `.min(last)`
/// clamp keeps the final code — which can never truly spill past the row's
/// last word, since rows are padded to a word boundary — from reading out
/// of bounds (the garbage bits it blends in are masked away).
#[inline(always)]
fn decode_generic(words: &[u64], b: usize, out: &mut [u32]) {
    let mask = (1u64 << b) - 1;
    let last = words.len() - 1;
    for (j, o) in out.iter_mut().enumerate() {
        let bit = j * b;
        let w = bit >> 6;
        let off = (bit & 63) as u32;
        let lo = words[w] >> off;
        let hi = (words[(w + 1).min(last)] << 1) << (63 - off);
        *o = ((j as u32) << b) | ((lo | hi) & mask) as u32;
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::Rng;

    #[test]
    fn roundtrip_all_b() {
        let mut rng = Rng::new(91);
        for b in 1..=16u32 {
            let k = 37; // deliberately not word-aligned
            let mut pc = PackedCodes::new(b, k);
            let mut rows = Vec::new();
            for _ in 0..50 {
                let row: Vec<u16> =
                    (0..k).map(|_| rng.below(1 << b) as u16).collect();
                pc.push_row(&row).unwrap();
                rows.push(row);
            }
            for (i, row) in rows.iter().enumerate() {
                assert_eq!(&pc.row(i), row, "b={b} row {i}");
            }
        }
    }

    #[test]
    fn cross_word_boundary_b12() {
        // b=12, k=37: bit offsets hit 60 → codes straddle word boundaries
        let mut pc = PackedCodes::new(12, 37);
        let row: Vec<u16> = (0..37).map(|j| (j * 111 % 4096) as u16).collect();
        pc.push_row(&row).unwrap();
        assert_eq!(pc.row(0), row);
    }

    #[test]
    fn rejects_out_of_range_codes() {
        let mut pc = PackedCodes::new(4, 3);
        assert!(pc.push_row(&[1, 2, 16]).is_err());
        assert_eq!(pc.n, 0); // failed push leaves no partial row
        assert!(pc.push_row(&[1, 2, 15]).is_ok());
    }

    #[test]
    fn storage_is_nbk_bits_up_to_row_padding() {
        let pc = PackedCodes::zeroed(8, 200, 1000);
        let ideal = pc.ideal_bytes() as f64;
        let actual = pc.storage_bytes() as f64;
        assert!(actual >= ideal);
        assert!(actual < 1.05 * ideal, "padding overhead too large");
    }

    #[test]
    fn set_get_random_access() {
        let mut rng = Rng::new(97);
        let mut pc = PackedCodes::zeroed(5, 64, 100);
        let mut mirror = vec![vec![0u16; 64]; 100];
        for _ in 0..5000 {
            let (r, j) = (rng.below_usize(100), rng.below_usize(64));
            let c = rng.below(32) as u16;
            pc.set(r, j, c);
            mirror[r][j] = c;
        }
        for r in 0..100 {
            assert_eq!(pc.row(r), mirror[r]);
        }
    }

    #[test]
    fn save_load_roundtrip() {
        let mut rng = Rng::new(101);
        let mut pc = PackedCodes::new(11, 23);
        for _ in 0..40 {
            let row: Vec<u16> = (0..23).map(|_| rng.below(1 << 11) as u16).collect();
            pc.push_row(&row).unwrap();
        }
        let mut buf = Vec::new();
        pc.save(&mut buf).unwrap();
        let back = PackedCodes::load(&buf[..]).unwrap();
        assert_eq!(pc, back);
        assert!(PackedCodes::load(&b"XXXX123"[..]).is_err());
    }

    #[test]
    fn truncate_bits_masks() {
        let mut pc = PackedCodes::new(16, 4);
        pc.push_row(&[0xABCD, 0x1234, 0xFFFF, 0x0080]).unwrap();
        // b_new == b must be the identity (regression: u16 shift wrap)
        let t16 = pc.truncate_bits(16).unwrap();
        assert_eq!(t16.row(0), pc.row(0));
        let t8 = pc.truncate_bits(8).unwrap();
        assert_eq!(t8.row(0), vec![0xCD, 0x34, 0xFF, 0x80]);
        let t1 = pc.truncate_bits(1).unwrap();
        assert_eq!(t1.row(0), vec![1, 0, 1, 0]);
        assert!(t8.truncate_bits(12).is_err());
    }

    #[test]
    fn truncate_k_prefixes() {
        let mut pc = PackedCodes::new(8, 6);
        pc.push_row(&[1, 2, 3, 4, 5, 6]).unwrap();
        let t = pc.truncate_k(3).unwrap();
        assert_eq!(t.row(0), vec![1, 2, 3]);
        assert_eq!(t.k, 3);
        assert!(pc.truncate_k(7).is_err());
    }

    #[test]
    fn words_from_words_roundtrip_and_clear() {
        let mut rng = Rng::new(77);
        let mut pc = PackedCodes::new(9, 29);
        for _ in 0..17 {
            let row: Vec<u16> = (0..29).map(|_| rng.below(1 << 9) as u16).collect();
            pc.push_row(&row).unwrap();
        }
        let back =
            PackedCodes::from_words(pc.b, pc.k, pc.n, pc.words().to_vec()).unwrap();
        assert_eq!(pc, back);
        // geometry mismatches are rejected, not UB
        assert!(PackedCodes::from_words(9, 29, 16, pc.words().to_vec()).is_err());
        assert!(PackedCodes::from_words(77, 29, 17, pc.words().to_vec()).is_err());
        let mut cleared = back;
        cleared.clear();
        assert_eq!(cleared.n, 0);
        assert!(cleared.words().is_empty());
        cleared.push_row(&[0; 29]).unwrap(); // still usable after clear
        assert_eq!(cleared.n, 1);
    }

    #[test]
    fn fill_from_le_bytes_reuses_the_buffer() {
        let mut rng = Rng::new(55);
        let mut pc = PackedCodes::new(6, 21);
        for _ in 0..9 {
            let row: Vec<u16> = (0..21).map(|_| rng.below(1 << 6) as u16).collect();
            pc.push_row(&row).unwrap();
        }
        let bytes: Vec<u8> = pc.words().iter().flat_map(|w| w.to_le_bytes()).collect();
        let mut scratch = PackedCodes::new(6, 21);
        scratch.reserve_rows(9);
        scratch.fill_from_le_bytes(9, &bytes).unwrap();
        assert_eq!(scratch, pc);
        // refill with fewer rows: geometry kept, contents replaced
        scratch.fill_from_le_bytes(3, &bytes[..3 * 8 * pc.stride()]).unwrap();
        assert_eq!(scratch.n, 3);
        assert_eq!(scratch.row(2), pc.row(2));
        // byte-count mismatches are typed errors
        assert!(scratch.fill_from_le_bytes(9, &bytes[..8]).is_err());
    }

    #[test]
    fn row_indices_match_get_for_every_b() {
        let mut rng = Rng::new(0xDECDE);
        // ragged k values: < LANES, % 8 != 0, 1, and word-straddling sizes
        for b in 1..=16u32 {
            for k in [1usize, 2, 3, 5, 8, 13, 21, 37, 64, 200] {
                let mut pc = PackedCodes::new(b, k);
                for _ in 0..7 {
                    let row: Vec<u16> =
                        (0..k).map(|_| rng.below(1 << b) as u16).collect();
                    pc.push_row(&row).unwrap();
                }
                let mut fast = vec![0u32; k];
                let mut slow = vec![0u32; k];
                for i in 0..pc.n {
                    pc.row_indices_into(i, &mut fast);
                    pc.row_indices_scalar_into(i, &mut slow);
                    assert_eq!(fast, slow, "b={b} k={k} row {i}");
                    // and both agree with the definition (j << b) + code
                    for (j, &t) in fast.iter().enumerate() {
                        assert_eq!(
                            t,
                            ((j << b) + pc.get(i, j) as usize) as u32,
                            "b={b} k={k} row {i} col {j}"
                        );
                    }
                }
            }
        }
    }

    #[test]
    fn row_indices_survive_buffer_refill() {
        // regression guard for the replay pattern: a scratch PackedCodes
        // refilled in place via fill_from_le_bytes must decode the *new*
        // contents (no stale per-buffer state is allowed anywhere).
        let mut rng = Rng::new(0xF111);
        let mk = |rng: &mut Rng| {
            let mut pc = PackedCodes::new(6, 21);
            for _ in 0..4 {
                let row: Vec<u16> = (0..21).map(|_| rng.below(64) as u16).collect();
                pc.push_row(&row).unwrap();
            }
            pc
        };
        let (a, b) = (mk(&mut rng), mk(&mut rng));
        let bytes_a: Vec<u8> = a.words().iter().flat_map(|w| w.to_le_bytes()).collect();
        let bytes_b: Vec<u8> = b.words().iter().flat_map(|w| w.to_le_bytes()).collect();
        let mut scratch = PackedCodes::new(6, 21);
        let mut got = vec![0u32; 21];
        let mut want = vec![0u32; 21];
        for bytes in [&bytes_a, &bytes_b, &bytes_a] {
            scratch.fill_from_le_bytes(4, bytes).unwrap();
            for i in 0..4 {
                scratch.row_indices_into(i, &mut got);
                scratch.row_indices_scalar_into(i, &mut want);
                assert_eq!(got, want, "row {i}");
            }
        }
    }

    #[test]
    fn extend_and_copy_row() {
        let mut a = PackedCodes::new(8, 16);
        let mut b = PackedCodes::new(8, 16);
        a.push_row(&[1; 16]).unwrap();
        b.push_row(&[2; 16]).unwrap();
        b.push_row(&[3; 16]).unwrap();
        a.extend(&b).unwrap();
        assert_eq!(a.n, 3);
        assert_eq!(a.row(2), vec![3; 16]);
        let mut c = PackedCodes::zeroed(8, 16, 3);
        c.copy_row_from(0, &a, 2);
        assert_eq!(c.row(0), vec![3; 16]);
    }
}
