//! Byte-oriented record compression for the hashed cache (varint + RLE).
//!
//! Cache v3 can store record payloads compressed (`preprocess
//! --cache-compress`).  The dependency policy is thiserror + xla only, so
//! this is a deliberately small std-only codec rather than a gzip binding:
//! run-length encoding over the payload bytes with LEB128 varint lengths.
//! Packed b-bit code streams compress when codes are skewed or rows carry
//! word-padding zeros (small b, unaligned k); labels compress whenever
//! classes arrive in runs.  On incompressible data the overhead is one tag
//! varint per literal run — bounded by [`max_compressed_len`], which the
//! reader uses to reject absurd stored lengths before allocating.
//!
//! ## Token stream
//!
//! A compressed payload is a sequence of tokens, each a LEB128 varint `v`
//! followed by its operand:
//!
//! ```text
//!   v = len << 1 | 0   literal run: the next `len` bytes verbatim
//!   v = len << 1 | 1   repeat run:  the next 1 byte, repeated `len` times
//! ```
//!
//! `len` is always ≥ 1; the stream ends exactly at the payload boundary.
//! Runs shorter than [`MIN_RUN`] are folded into literals (a run token
//! costs ≥ 2 bytes, so 2-byte runs never pay for themselves).

use crate::{Error, Result};

/// Shortest repeat run worth a run token (tag varint + value byte ≤ 3
/// bytes, so runs of 4+ always win; 3-byte runs only break even).
const MIN_RUN: usize = 4;

/// Append `v` as a LEB128 varint.
fn put_varint(dst: &mut Vec<u8>, mut v: u64) {
    loop {
        let byte = (v & 0x7f) as u8;
        v >>= 7;
        if v == 0 {
            dst.push(byte);
            return;
        }
        dst.push(byte | 0x80);
    }
}

/// Read a LEB128 varint from `src[*pos..]`, advancing `pos`.
fn get_varint(src: &[u8], pos: &mut usize) -> Result<u64> {
    // single-byte fast path: token lengths are almost always < 128, so
    // the decode loop below is the exception, not the rule
    if let Some(&b0) = src.get(*pos) {
        if b0 < 0x80 {
            *pos += 1;
            return Ok(b0 as u64);
        }
    }
    let mut v = 0u64;
    let mut shift = 0u32;
    loop {
        let &byte = src
            .get(*pos)
            .ok_or_else(|| Error::InvalidArg("compressed record truncated in varint".into()))?;
        *pos += 1;
        if shift >= 64 {
            return Err(Error::InvalidArg("compressed record varint overflows u64".into()));
        }
        v |= ((byte & 0x7f) as u64) << shift;
        if byte & 0x80 == 0 {
            return Ok(v);
        }
        shift += 7;
    }
}

/// Worst-case compressed size for a `raw` -byte payload: one literal-run
/// tag varint per chunk of incompressible bytes plus the bytes themselves.
/// The encoder emits maximal literals, so tags amortize to ≤ 10 bytes per
/// `u64::MAX`-capped run; a single literal covering the whole payload
/// costs `varint(raw << 1 | 0)` ≤ 10 bytes.  16 leaves slack for an
/// empty-payload token.
pub fn max_compressed_len(raw: u64) -> u64 {
    raw + 16
}

/// Length of the run of bytes equal to `src[i]` starting at `i`.
/// Word-at-a-time: XOR 8-byte windows against the splatted byte and
/// locate the first differing byte by its position in native byte order —
/// the exact same count the byte-wise scan produces (the parity test in
/// `tests/simd_kernels.rs` reimplements `compress` byte-wise and requires
/// identical output), at ~8× fewer comparisons on long runs.
#[inline]
fn run_len(src: &[u8], i: usize) -> usize {
    let b = src[i];
    let splat = u64::from_ne_bytes([b; 8]);
    let mut j = i + 1;
    while j + 8 <= src.len() {
        let word = u64::from_ne_bytes(src[j..j + 8].try_into().unwrap());
        let diff = word ^ splat;
        if diff != 0 {
            let first = diff.to_ne_bytes().iter().position(|&x| x != 0).unwrap();
            return j + first - i;
        }
        j += 8;
    }
    while j < src.len() && src[j] == b {
        j += 1;
    }
    j - i
}

/// RLE-compress `src` into `dst` (cleared first).  Deterministic: the same
/// input always produces the same bytes, so compressed caches stay
/// byte-comparable across runs.
pub fn compress(src: &[u8], dst: &mut Vec<u8>) {
    dst.clear();
    dst.reserve(src.len() / 8);
    let mut lit_start = 0usize; // start of the pending literal run
    let mut i = 0usize;
    while i < src.len() {
        let run = run_len(src, i);
        if run >= MIN_RUN {
            if lit_start < i {
                put_varint(dst, ((i - lit_start) as u64) << 1);
                dst.extend_from_slice(&src[lit_start..i]);
            }
            put_varint(dst, ((run as u64) << 1) | 1);
            dst.push(src[i]);
            i += run;
            lit_start = i;
        } else {
            i += run; // short run rides along inside the literal
        }
    }
    if lit_start < src.len() {
        put_varint(dst, ((src.len() - lit_start) as u64) << 1);
        dst.extend_from_slice(&src[lit_start..]);
    }
}

/// Decompress `src` into `dst` (cleared first), which must come out to
/// exactly `expect_len` bytes — the reader knows every record's raw size
/// from its row count, so a mismatch is corruption, not a guess.
pub fn decompress(src: &[u8], dst: &mut Vec<u8>, expect_len: usize) -> Result<()> {
    dst.clear();
    dst.reserve(expect_len);
    let mut pos = 0usize;
    while pos < src.len() {
        let v = get_varint(src, &mut pos)?;
        let len = (v >> 1) as usize;
        if len == 0 || dst.len() + len > expect_len {
            return Err(Error::InvalidArg(format!(
                "compressed record expands past its raw size ({} + {len} > {expect_len})",
                dst.len()
            )));
        }
        if v & 1 == 1 {
            let &value = src.get(pos).ok_or_else(|| {
                Error::InvalidArg("compressed record truncated in repeat run".into())
            })?;
            pos += 1;
            dst.resize(dst.len() + len, value);
        } else {
            let lit = src.get(pos..pos + len).ok_or_else(|| {
                Error::InvalidArg("compressed record truncated in literal run".into())
            })?;
            dst.extend_from_slice(lit);
            pos += len;
        }
    }
    if dst.len() != expect_len {
        return Err(Error::InvalidArg(format!(
            "compressed record decodes to {} bytes, expected {expect_len}",
            dst.len()
        )));
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::Rng;

    fn roundtrip(src: &[u8]) -> Vec<u8> {
        let mut comp = Vec::new();
        compress(src, &mut comp);
        let mut back = Vec::new();
        decompress(&comp, &mut back, src.len()).unwrap();
        assert!(
            comp.len() as u64 <= max_compressed_len(src.len() as u64),
            "{} > bound {}",
            comp.len(),
            max_compressed_len(src.len() as u64)
        );
        assert_eq!(back, src);
        comp
    }

    #[test]
    fn roundtrips_edge_cases() {
        roundtrip(&[]);
        roundtrip(&[7]);
        roundtrip(&[1, 2, 3]);
        roundtrip(&[0; 1000]);
        roundtrip(&[0xAB; 3]); // below MIN_RUN: stays literal
        let mixed: Vec<u8> = (0..512u32)
            .flat_map(|i| if i % 3 == 0 { vec![0u8; 9] } else { vec![(i % 251) as u8] })
            .collect();
        roundtrip(&mixed);
    }

    #[test]
    fn roundtrips_random_payloads() {
        let mut rng = Rng::new(0xC0DEC);
        for n in [1usize, 17, 255, 256, 257, 4096] {
            // incompressible
            let noise: Vec<u8> = (0..n).map(|_| rng.next_u64() as u8).collect();
            roundtrip(&noise);
            // runs-heavy (zero padding interleaved with noise)
            let runs: Vec<u8> = (0..n)
                .map(|i| if (i / 16) % 2 == 0 { 0 } else { rng.next_u64() as u8 })
                .collect();
            roundtrip(&runs);
        }
    }

    #[test]
    fn compresses_runs_and_bounds_noise() {
        let zeros = [0u8; 4096];
        let comp = roundtrip(&zeros);
        assert!(comp.len() < 16, "all-zero payload must collapse, got {}", comp.len());
        let mut rng = Rng::new(9);
        let noise: Vec<u8> = (0..4096).map(|_| rng.next_u64() as u8).collect();
        let comp = roundtrip(&noise);
        assert!(comp.len() <= noise.len() + 16);
    }

    #[test]
    fn corrupt_streams_are_typed_errors() {
        let mut comp = Vec::new();
        compress(&[5u8; 100], &mut comp);
        let mut out = Vec::new();
        // wrong expected length
        assert!(decompress(&comp, &mut out, 99).is_err());
        assert!(decompress(&comp, &mut out, 101).is_err());
        // truncated stream
        assert!(decompress(&comp[..comp.len() - 1], &mut out, 100).is_err());
        // declared length overruns the raw size
        let mut bogus = Vec::new();
        put_varint(&mut bogus, (1000u64 << 1) | 1);
        bogus.push(0xFF);
        assert!(decompress(&bogus, &mut out, 100).is_err());
        // varint that never terminates
        assert!(decompress(&[0x80, 0x80, 0x80], &mut out, 10).is_err());
        // zero-length token is invalid, not an infinite loop
        assert!(decompress(&[0x00], &mut out, 10).is_err());
    }

    #[test]
    fn run_len_matches_bytewise_scan() {
        let mut rng = Rng::new(0x41E);
        for n in [1usize, 7, 8, 9, 31, 64, 513] {
            // biased toward repeats so runs cross word boundaries often
            let data: Vec<u8> =
                (0..n).map(|_| (rng.below(3)) as u8).collect();
            for i in 0..n {
                let mut want = 1usize;
                while i + want < n && data[i + want] == data[i] {
                    want += 1;
                }
                assert_eq!(run_len(&data, i), want, "n={n} i={i}");
            }
        }
    }

    #[test]
    fn varint_roundtrip() {
        let mut buf = Vec::new();
        for v in [0u64, 1, 127, 128, 300, 1 << 20, u64::MAX] {
            buf.clear();
            put_varint(&mut buf, v);
            let mut pos = 0;
            assert_eq!(get_varint(&buf, &mut pos).unwrap(), v);
            assert_eq!(pos, buf.len());
        }
    }
}
