//! On-disk hashed-dataset cache — hash a corpus once, train on it many
//! times.
//!
//! The paper's economics (Sections 1 and 6) hinge on preprocessing being a
//! one-time cost amortized over every (solver, C, b, k≤K) sweep that
//! follows; fwumious wabbit ships the same shape as its "input cache"
//! (scenario 1 of its BENCHMARK.md: generate the cache once, then run many
//! fast training passes over it).  This module is that artifact for packed
//! b-bit codes — whichever [`FeatureEncoder`](crate::encode::encoder)
//! scheme produced them (b-bit minwise, OPH, ...): a sequential,
//! checksummed record stream a 200GB-scale corpus can be written to and
//! replayed from in constant memory.  Since v3 the file is also
//! *seekable*: a chunk-index footer makes any record addressable without a
//! pre-scan, so a reader pool ([`crate::coordinator::replay`]) can fan
//! replay out across cores.
//!
//! ## Layout (all integers little-endian)
//!
//! v3 (current — written by every [`CacheWriter`]):
//!
//! ```text
//!   magic  b"BBHC"
//!   u32    format version (= 3)
//!   u32    scheme tag     ┐
//!   u32    p0             │ the EncoderSpec, via
//!   u64    p1             │ EncoderSpec::header_fields — any reader can
//!   u64    p2             │ verify a model trained from this cache used
//!   u64    seed           ┘ the same encoder family
//!   u32    flags          bit 0: record payloads are RLE-compressed
//!                         (encode::codec); other bits reserved (readers
//!                         reject files with unknown bits set)
//!   u64    raw bytes      total uncompressed payload bytes  (patched on
//!   u64    stored bytes   total on-disk payload bytes        finalize)
//!   u64    n              total rows (patched on finalize; u64::MAX while
//!                         the writer is still open — readers reject it)
//!   repeated chunk records:
//!     u32    rows in this chunk
//!     u64    stored payload bytes
//!     [u8]   payload: rows labels then rows·stride packed words — raw, or
//!            codec-compressed when flag bit 0 is set
//!     u64    FNV-1a checksum over the rows field + stored payload bytes
//!   chunk-index footer (written by finalize; 20 bytes per record):
//!     u64    byte offset of the record (its rows field)
//!     u32    rows in the record
//!     u64    the record's checksum (== the one stored inline)
//!   trailer (32 bytes, fixed at end-of-file):
//!     u64    byte offset of the first index entry
//!     u64    record count
//!     u64    FNV-1a checksum over the index entry bytes
//!     [u8;8] b"BBHCIDX1"
//! ```
//!
//! The footer is strictly additive: a sequential [`CacheReader`] stops
//! after `n` rows and never sees it, and a truncated/corrupt footer makes
//! [`ChunkIndex::load`] report "no index" (callers fall back to the
//! sequential scan with a warning) rather than failing the file.
//!
//! The cache can also carry a derived *index-snapshot sidecar*: `bbit-mh
//! similar-index` replays a cache once and writes a `BBMHSIM1` file (see
//! [`crate::similarity::snapshot`]) holding the banded-LSH tables +
//! signatures for the online `/similar` path, so serve replicas load the
//! prebuilt index instead of re-replaying the cache at startup.  The
//! sidecar embeds the same `header_fields` spec block as the cache header,
//! keeping the family check intact across the derivation.
//!
//! v2 (legacy — still readable): the v3 header without the
//! `flags`/`raw`/`stored` fields, no footer, payloads never compressed.
//! v1 (legacy — still readable; always b-bit minwise): fixed
//! `b/k/d/seed/n` header, records as in v2.
//!
//! Only packed-code schemes are cacheable (the record payload *is* the
//! [`PackedCodes`] word stream); the header's tag space covers the
//! sparse schemes too so the format never needs another bump to learn
//! them.  Records are chunk-granular on purpose: the writer is fed by the
//! pipeline's in-order collector ([`CacheSink`](crate::coordinator::sink)),
//! and the reader replays the identical chunk stream into the streaming
//! trainer, so `hash → cache → train` and `hash → train` see byte-identical
//! data in identical order.
//!
//! ## Durable commits and resume (the crash-safety protocol)
//!
//! A 200GB preprocess runs for hours; `preprocess --cache-out` therefore
//! never writes the destination path directly.  The durable writer
//! ([`CacheWriter::create_durable`]) follows the tmp/rename protocol of
//! [`crate::util::atomic_file`]:
//!
//! 1. records stream into `<cache>.tmp`, with a *resume journal* sidecar
//!    `<cache>.tmp.resume` recording, per pipeline block, a checksummed
//!    fixed-width entry: records written, cache byte offset, row/byte
//!    counters, and the input byte offset + line number the next block
//!    starts at;
//! 2. every `sync_chunks` blocks the data file is flushed + fsync'd and
//!    then the journal is flushed + fsync'd (data before journal, so a
//!    journal entry never outlives the bytes it describes — and even if
//!    OS writeback reorders them, resume *validates* rather than trusts);
//! 3. `finalize` writes the index footer, patches the header, fsyncs the
//!    tmp, atomically renames it onto the destination, fsyncs the parent
//!    directory, and deletes the journal.
//!
//! A reader thus only ever sees the destination path as absent or
//! complete.  `preprocess --resume` ([`CacheWriter::resume_durable`])
//! recovers a crashed run from the leftovers: it re-scans `.tmp` record
//! by record (checksums verified) to find where valid data ends, picks
//! the **latest journal entry whose claimed prefix fully validates**,
//! truncates the torn tail back to that entry, and hands the caller the
//! input offset + line number to restart ingest at.  Because pipeline
//! blocks are carved at newline boundaries, re-carving from that offset
//! reproduces the identical block/record stream — a resumed cache is
//! byte-identical to one written by an uninterrupted run.
//!
//! Failpoints [`crate::faults::site::CACHE_WRITE_RECORD`] (torn-write /
//! error / delay injection per record) and
//! [`crate::faults::site::CACHE_FINALIZE`] (crash before commit) sit on
//! this path so the recovery story stays tested, not aspirational.

use std::fs::{File, OpenOptions};
use std::io::{BufReader, BufWriter, Read, Seek, SeekFrom, Write};
use std::path::{Path, PathBuf};

use crate::encode::codec;
use crate::encode::encoder::EncoderSpec;
use crate::encode::expansion::BbitDataset;
use crate::encode::packed::PackedCodes;
use crate::faults;
use crate::util::atomic_file;
use crate::{Error, Result};

/// File magic for the hashed-chunk cache.
pub const CACHE_MAGIC: &[u8; 4] = b"BBHC";
/// Current format version (v3: chunk-index footer + optional compression).
pub const CACHE_VERSION: u32 = 3;
/// Oldest version the reader still accepts.
pub const CACHE_VERSION_MIN: u32 = 1;
/// v2 header bytes before the first record
/// (magic + version + tag + p0 + p1 + p2 + seed + n).
pub const HEADER_BYTES_V2: u64 = 4 + 4 + 4 + 4 + 8 + 8 + 8 + 8;
/// v3 header bytes before the first record (v2's fields + flags + the two
/// payload byte totals).
pub const HEADER_BYTES_V3: u64 = 4 + 4 + 4 + 4 + 8 + 8 + 8 + 4 + 8 + 8 + 8;
/// Byte offset of the v3 `raw bytes` field — `raw`/`stored`/`n` are
/// contiguous so `finalize` patches all three with one write.
const STATS_OFFSET_V3: u64 = HEADER_BYTES_V3 - 24;
/// Placeholder `n` while a writer is open; readers reject it.
const N_UNFINALIZED: u64 = u64::MAX;
/// v3 flag bit 0: record payloads are compressed with [`codec`].
pub const CACHE_FLAG_COMPRESSED: u32 = 1;
/// Bytes per chunk-index footer entry (offset + rows + checksum).
pub const INDEX_ENTRY_BYTES: u64 = 8 + 4 + 8;
/// Bytes of the fixed trailer at end-of-file.
pub const TRAILER_BYTES: u64 = 8 + 8 + 8 + 8;
/// Trailer magic: "BBHC index v1".
const TRAILER_MAGIC: &[u8; 8] = b"BBHCIDX1";
/// Resume-journal magic ("BBHC journal v1").
const JOURNAL_MAGIC: &[u8; 8] = b"BBHCJRN1";
/// Bytes per resume-journal entry: records, cache offset, n, raw bytes,
/// stored bytes, input offset, next line, FNV-1a over the first 56 bytes.
const JOURNAL_ENTRY_BYTES: usize = 8 * 8;
/// Default blocks between fsync'd journal flushes on the durable path.
pub const DEFAULT_SYNC_CHUNKS: usize = 64;

/// The encoder recipe + row count stored in the cache header.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct CacheMeta {
    /// The encoder the cached codes were produced with.
    pub spec: EncoderSpec,
    /// Total rows across all records.
    pub n: u64,
    /// Record payloads are stored RLE-compressed (v3 flag bit 0).
    pub compressed: bool,
    /// Total uncompressed payload bytes across all records (0 for pre-v3
    /// headers, which did not record byte totals).
    pub raw_bytes: u64,
    /// Total on-disk payload bytes (== `raw_bytes` for uncompressed v3
    /// files; 0 for pre-v3 headers).
    pub stored_bytes: u64,
}

impl CacheMeta {
    /// Encoded dimensionality (2^b·k for packed schemes) a solver trains
    /// against.
    pub fn expanded_dim(&self) -> usize {
        self.spec.output_dim()
    }
}

/// Incremental FNV-1a (64-bit) — per-record integrity, not cryptographic.
struct Fnv1a(u64);

impl Fnv1a {
    fn new() -> Self {
        Fnv1a(0xcbf2_9ce4_8422_2325)
    }

    fn update(&mut self, bytes: &[u8]) {
        for &byte in bytes {
            self.0 ^= byte as u64;
            self.0 = self.0.wrapping_mul(0x0000_0100_0000_01b3);
        }
    }

    fn finish(&self) -> u64 {
        self.0
    }
}

/// The packed-geometry (b, codes-per-row, stride) of a cacheable spec.
fn packed_geometry(spec: &EncoderSpec) -> Result<(u32, usize, usize)> {
    let (b, k) = spec.packed_geometry().ok_or_else(|| {
        Error::InvalidArg(format!(
            "cache stores packed b-bit codes; encoder scheme {:?} emits sparse rows",
            spec.scheme()
        ))
    })?;
    Ok((b, k, (k * b as usize).div_ceil(64)))
}

/// Writer knobs beyond the encoder spec.
#[derive(Clone, Copy, Debug, Default)]
pub struct CacheWriteOptions {
    /// RLE-compress record payloads ([`codec`]; `preprocess
    /// --cache-compress`).  Transparent on read — the v3 header flag tells
    /// the reader to decompress.
    pub compress: bool,
}

/// Buffered, append-only cache writer.  Records go out as chunks arrive;
/// [`finalize`](Self::finalize) writes the chunk-index footer and patches
/// the row/byte counts into the header.
pub struct CacheWriter<W: Write + Seek> {
    out: W,
    meta: CacheMeta,
    b: u32,
    k: usize,
    stride: usize,
    finalized: bool,
    /// Byte offset the next record will land at (header + records so far).
    offset: u64,
    /// One entry per record written — becomes the v3 footer.
    index: Vec<ChunkIndexEntry>,
    /// Reusable record-payload staging buffer (labels + words serialized
    /// once, then checksummed and written as single bulk calls).
    scratch: Vec<u8>,
    /// Compressed-payload staging (used only with `compress`).
    comp: Vec<u8>,
    /// tmp/rename + journal state for file-backed durable writers
    /// (`None` for plain writers and in-memory cursors).
    durable: Option<DurableState>,
}

/// Where a resumed `preprocess` run picks its input back up — the payload
/// of the latest resume-journal entry whose cache prefix validated.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct ResumePoint {
    /// Records already committed to the cache.
    pub records: u64,
    /// Rows already committed.
    pub rows: u64,
    /// Input byte offset the next pipeline block starts at.
    pub input_offset: u64,
    /// 1-based line number of the first unprocessed input line.
    pub next_line: u64,
}

struct DurableState {
    tmp: PathBuf,
    dst: PathBuf,
    journal_path: PathBuf,
    journal: BufWriter<File>,
    /// Blocks between fsync'd flushes of data-then-journal.
    sync_chunks: usize,
    marks_since_sync: usize,
}

/// The resume-journal sidecar for a cache destination (`<dst>.tmp.resume`).
pub fn journal_path(dst: &Path) -> PathBuf {
    let mut os = atomic_file::tmp_path(dst).into_os_string();
    os.push(".resume");
    PathBuf::from(os)
}

struct JournalEntry {
    records: u64,
    cache_offset: u64,
    n: u64,
    raw_bytes: u64,
    stored_bytes: u64,
    input_offset: u64,
    next_line: u64,
}

impl JournalEntry {
    fn to_bytes(&self) -> [u8; JOURNAL_ENTRY_BYTES] {
        let mut buf = [0u8; JOURNAL_ENTRY_BYTES];
        for (i, v) in [
            self.records,
            self.cache_offset,
            self.n,
            self.raw_bytes,
            self.stored_bytes,
            self.input_offset,
            self.next_line,
        ]
        .iter()
        .enumerate()
        {
            buf[i * 8..i * 8 + 8].copy_from_slice(&v.to_le_bytes());
        }
        let mut sum = Fnv1a::new();
        sum.update(&buf[..56]);
        buf[56..64].copy_from_slice(&sum.finish().to_le_bytes());
        buf
    }

    fn from_bytes(buf: &[u8; JOURNAL_ENTRY_BYTES]) -> Option<JournalEntry> {
        let mut sum = Fnv1a::new();
        sum.update(&buf[..56]);
        let stored = u64::from_le_bytes(buf[56..64].try_into().unwrap());
        if stored != sum.finish() {
            return None;
        }
        let f = |i: usize| u64::from_le_bytes(buf[i * 8..i * 8 + 8].try_into().unwrap());
        Some(JournalEntry {
            records: f(0),
            cache_offset: f(1),
            n: f(2),
            raw_bytes: f(3),
            stored_bytes: f(4),
            input_offset: f(5),
            next_line: f(6),
        })
    }
}

impl CacheWriter<BufWriter<File>> {
    /// Create (truncating) a cache file for the given encoder spec.
    ///
    /// This writes `path` directly (no tmp/rename): the legacy shape, kept
    /// for callers that manage their own commit.  `preprocess` uses
    /// [`create_durable`](Self::create_durable).
    pub fn create<P: AsRef<Path>>(path: P, spec: &EncoderSpec) -> Result<Self> {
        CacheWriter::create_opts(path, spec, CacheWriteOptions::default())
    }

    /// [`create`](Self::create) with explicit [`CacheWriteOptions`].
    pub fn create_opts<P: AsRef<Path>>(
        path: P,
        spec: &EncoderSpec,
        opts: CacheWriteOptions,
    ) -> Result<Self> {
        CacheWriter::with_options(
            BufWriter::with_capacity(1 << 20, File::create(path)?),
            spec,
            opts,
        )
    }

    /// Create a crash-safe writer: records stream into `<path>.tmp` with a
    /// `<path>.tmp.resume` journal, and [`finalize`](Self::finalize)
    /// atomically renames the tmp onto `path` (see the module docs).  Any
    /// stale leftovers from an earlier crash are discarded.
    pub fn create_durable<P: AsRef<Path>>(
        path: P,
        spec: &EncoderSpec,
        opts: CacheWriteOptions,
        sync_chunks: usize,
    ) -> Result<Self> {
        let dst = path.as_ref().to_path_buf();
        let tmp = atomic_file::tmp_path(&dst);
        let jpath = journal_path(&dst);
        let _ = std::fs::remove_file(&tmp);
        let _ = std::fs::remove_file(&jpath);
        let mut journal = BufWriter::new(File::create(&jpath)?);
        journal.write_all(JOURNAL_MAGIC)?;
        journal.flush()?;
        let out = BufWriter::with_capacity(1 << 20, File::create(&tmp)?);
        let mut w = CacheWriter::with_options(out, spec, opts)?;
        w.durable = Some(DurableState {
            tmp,
            dst,
            journal_path: jpath,
            journal,
            sync_chunks: sync_chunks.max(1),
            marks_since_sync: 0,
        });
        Ok(w)
    }

    /// Reopen a crashed durable run for `path`.  Returns `Ok(None)` when
    /// there is nothing usable to resume (no `.tmp`, no journal, or an
    /// unreadable tmp header) — the caller starts fresh.  On success the
    /// writer is positioned after the last journaled-and-validated record
    /// and the [`ResumePoint`] says where to restart ingest.
    ///
    /// The spec and options must match the crashed run: resuming under a
    /// different encoder or compression flag is a typed error, not silent
    /// corruption.
    pub fn resume_durable<P: AsRef<Path>>(
        path: P,
        spec: &EncoderSpec,
        opts: CacheWriteOptions,
        sync_chunks: usize,
    ) -> Result<Option<(Self, ResumePoint)>> {
        let dst = path.as_ref().to_path_buf();
        let tmp = atomic_file::tmp_path(&dst);
        let jpath = journal_path(&dst);
        if !tmp.exists() || !jpath.exists() {
            return Ok(None);
        }
        // The partial header: same fields as a finished v3 cache, but `n`
        // may still be the unfinalized placeholder.
        let (tmp_spec, tmp_compressed) = match read_partial_header(&tmp) {
            Ok(v) => v,
            Err(_) => return Ok(None),
        };
        if tmp_spec != *spec {
            return Err(Error::InvalidArg(format!(
                "--resume spec mismatch: partial cache was written with {:?}, this run asks for {:?}",
                tmp_spec, spec
            )));
        }
        if tmp_compressed != opts.compress {
            return Err(Error::InvalidArg(
                "--resume compression mismatch: partial cache and this run disagree on \
                 --cache-compress"
                    .into(),
            ));
        }
        // Where does valid data actually end?  Scan record by record,
        // checksums verified; the scan result is the ground truth the
        // journal is checked against.
        let (scanned, _valid_end) = scan_records(&tmp, spec, opts.compress)?;
        // Offset after each scanned record prefix (scan_offsets[i] = end of
        // record i-1), so journal claims can be checked exactly.
        let mut scan_offsets = Vec::with_capacity(scanned.len() + 1);
        scan_offsets.push(HEADER_BYTES_V3);
        for (i, e) in scanned.iter().enumerate() {
            let next = match scanned.get(i + 1) {
                Some(n) => n.offset,
                None => _valid_end,
            };
            debug_assert!(next > e.offset);
            scan_offsets.push(next);
        }
        let entries = read_journal(&jpath);
        // Latest journal entry whose claimed prefix fully validated.
        let mut chosen = JournalEntry {
            records: 0,
            cache_offset: HEADER_BYTES_V3,
            n: 0,
            raw_bytes: 0,
            stored_bytes: 0,
            input_offset: 0,
            next_line: 1,
        };
        let mut chosen_idx = 0usize; // journal entries kept (excl. implicit baseline)
        for (i, e) in entries.iter().enumerate() {
            let r = e.records as usize;
            if r <= scanned.len() && scan_offsets[r] == e.cache_offset {
                chosen = JournalEntry {
                    records: e.records,
                    cache_offset: e.cache_offset,
                    n: e.n,
                    raw_bytes: e.raw_bytes,
                    stored_bytes: e.stored_bytes,
                    input_offset: e.input_offset,
                    next_line: e.next_line,
                };
                chosen_idx = i + 1;
            }
        }
        // Truncate the torn tail (data and journal) back to the chosen
        // entry, then reopen both for appending.
        let data = OpenOptions::new().read(true).write(true).open(&tmp)?;
        data.set_len(chosen.cache_offset)?;
        let jfile = OpenOptions::new().read(true).write(true).open(&jpath)?;
        jfile.set_len((JOURNAL_MAGIC.len() + chosen_idx * JOURNAL_ENTRY_BYTES) as u64)?;
        let mut out = BufWriter::with_capacity(1 << 20, data);
        out.seek(SeekFrom::Start(chosen.cache_offset))?;
        let mut journal = BufWriter::new(jfile);
        journal.seek(SeekFrom::End(0))?;
        let mut w = CacheWriter::with_options_resumed(out, spec, opts)?;
        w.meta.n = chosen.n;
        w.meta.raw_bytes = chosen.raw_bytes;
        w.meta.stored_bytes = chosen.stored_bytes;
        w.offset = chosen.cache_offset;
        w.index = scanned[..chosen.records as usize].to_vec();
        w.durable = Some(DurableState {
            tmp,
            dst,
            journal_path: jpath,
            journal,
            sync_chunks: sync_chunks.max(1),
            marks_since_sync: 0,
        });
        let point = ResumePoint {
            records: chosen.records,
            rows: chosen.n,
            input_offset: chosen.input_offset,
            next_line: chosen.next_line,
        };
        Ok(Some((w, point)))
    }
}

/// Read the v3 header of a (possibly unfinalized) partial cache, returning
/// its spec and compression flag.
fn read_partial_header(path: &Path) -> Result<(EncoderSpec, bool)> {
    let mut r = BufReader::new(File::open(path)?);
    let mut magic = [0u8; 4];
    r.read_exact(&mut magic)?;
    if &magic != CACHE_MAGIC {
        return Err(Error::InvalidArg("bad cache magic (not a BBHC file)".into()));
    }
    let mut u32buf = [0u8; 4];
    let mut u64buf = [0u8; 8];
    r.read_exact(&mut u32buf)?;
    if u32::from_le_bytes(u32buf) != CACHE_VERSION {
        return Err(Error::InvalidArg("partial cache is not v3".into()));
    }
    r.read_exact(&mut u32buf)?;
    let tag = u32::from_le_bytes(u32buf);
    r.read_exact(&mut u32buf)?;
    let p0 = u32::from_le_bytes(u32buf);
    let mut next_u64 = |r: &mut BufReader<File>| -> Result<u64> {
        r.read_exact(&mut u64buf)?;
        Ok(u64::from_le_bytes(u64buf))
    };
    let p1 = next_u64(&mut r)?;
    let p2 = next_u64(&mut r)?;
    let seed = next_u64(&mut r)?;
    r.read_exact(&mut u32buf)?;
    let flags = u32::from_le_bytes(u32buf);
    if flags & !CACHE_FLAG_COMPRESSED != 0 {
        return Err(Error::InvalidArg(format!(
            "partial cache uses unknown feature flags {flags:#x}"
        )));
    }
    let spec = EncoderSpec::from_header_fields(tag, p0, p1, p2, seed)?;
    spec.validate()?;
    Ok((spec, flags & CACHE_FLAG_COMPRESSED != 0))
}

/// Walk the record region of a partial cache from the first record, keeping
/// every record that fully decodes with a matching checksum.  Returns the
/// entries (in file order) and the byte offset where validity ends.
fn scan_records(
    path: &Path,
    spec: &EncoderSpec,
    compressed: bool,
) -> Result<(Vec<ChunkIndexEntry>, u64)> {
    let meta = CacheMeta {
        spec: *spec,
        n: 0,
        compressed,
        raw_bytes: 0,
        stored_bytes: 0,
    };
    let mut decoder = RecordDecoder::for_meta(&meta)?;
    let (b, k, _stride) = packed_geometry(spec)?;
    let mut codes = PackedCodes::new(b, k);
    let mut labels = Vec::new();
    let mut r = BufReader::with_capacity(1 << 20, File::open(path)?);
    let len = r.seek(SeekFrom::End(0))?;
    r.seek(SeekFrom::Start(HEADER_BYTES_V3))?;
    let mut offset = HEADER_BYTES_V3.min(len);
    let mut entries = Vec::new();
    let mut row = 0u64;
    while offset < len {
        match decoder.read_from(&mut r, row, u32::MAX as u64, &mut codes, &mut labels) {
            Ok((rows, checksum)) => {
                let entry = ChunkIndexEntry {
                    offset,
                    rows: rows as u32,
                    checksum,
                };
                offset = r.stream_position()?;
                row += rows as u64;
                entries.push(entry);
            }
            Err(_) => break,
        }
    }
    Ok((entries, offset))
}

/// All checksum-valid entries at the front of a resume journal (an invalid
/// or torn entry ends the walk; a bad header yields no entries).
fn read_journal(path: &Path) -> Vec<JournalEntry> {
    let mut out = Vec::new();
    let mut r = match File::open(path) {
        Ok(f) => BufReader::new(f),
        Err(_) => return out,
    };
    let mut magic = [0u8; 8];
    if r.read_exact(&mut magic).is_err() || &magic != JOURNAL_MAGIC {
        return out;
    }
    let mut buf = [0u8; JOURNAL_ENTRY_BYTES];
    while r.read_exact(&mut buf).is_ok() {
        match JournalEntry::from_bytes(&buf) {
            Some(e) => out.push(e),
            None => break,
        }
    }
    out
}

impl<W: Write + Seek> CacheWriter<W> {
    pub fn new(out: W, spec: &EncoderSpec) -> Result<Self> {
        CacheWriter::with_options(out, spec, CacheWriteOptions::default())
    }

    pub fn with_options(mut out: W, spec: &EncoderSpec, opts: CacheWriteOptions) -> Result<Self> {
        spec.validate()?;
        let (tag, p0, p1, p2, seed) = spec.header_fields();
        let flags = if opts.compress { CACHE_FLAG_COMPRESSED } else { 0 };
        out.write_all(CACHE_MAGIC)?;
        out.write_all(&CACHE_VERSION.to_le_bytes())?;
        out.write_all(&tag.to_le_bytes())?;
        out.write_all(&p0.to_le_bytes())?;
        for v in [p1, p2, seed] {
            out.write_all(&v.to_le_bytes())?;
        }
        out.write_all(&flags.to_le_bytes())?;
        for v in [0u64, 0u64, N_UNFINALIZED] {
            out.write_all(&v.to_le_bytes())?;
        }
        CacheWriter::with_options_resumed(out, spec, opts)
    }

    /// Build the writer state over `out` without emitting a header — the
    /// resume path reopens a tmp whose header already exists on disk.
    fn with_options_resumed(out: W, spec: &EncoderSpec, opts: CacheWriteOptions) -> Result<Self> {
        spec.validate()?;
        let (b, k, stride) = packed_geometry(spec)?;
        Ok(CacheWriter {
            out,
            meta: CacheMeta {
                spec: *spec,
                n: 0,
                compressed: opts.compress,
                raw_bytes: 0,
                stored_bytes: 0,
            },
            b,
            k,
            stride,
            finalized: false,
            offset: HEADER_BYTES_V3,
            index: Vec::new(),
            scratch: Vec::new(),
            comp: Vec::new(),
            durable: None,
        })
    }

    /// Rows written so far.
    pub fn rows_written(&self) -> u64 {
        self.meta.n
    }

    /// Header metadata as written so far (byte totals grow per chunk).
    pub fn meta(&self) -> CacheMeta {
        self.meta
    }

    /// Append one hashed chunk as a checksummed record.
    pub fn write_chunk(&mut self, codes: &PackedCodes, labels: &[i8]) -> Result<()> {
        if self.finalized {
            return Err(Error::InvalidArg("cache writer already finalized".into()));
        }
        if codes.b != self.b || codes.k != self.k {
            return Err(Error::InvalidArg(format!(
                "chunk geometry (b={}, k={}) does not match cache (b={}, k={})",
                codes.b, codes.k, self.b, self.k
            )));
        }
        if codes.n != labels.len() {
            return Err(Error::InvalidArg(format!(
                "chunk has {} rows but {} labels",
                codes.n,
                labels.len()
            )));
        }
        if codes.n == 0 {
            return Ok(()); // empty chunks carry no information
        }
        let rows = u32::try_from(codes.n)
            .map_err(|_| Error::InvalidArg("chunk larger than u32 rows".into()))?;
        // stage the payload once (labels as two's-complement bytes, then
        // little-endian words) so checksum + IO run over whole slices
        self.scratch.clear();
        self.scratch.reserve(codes.n + 8 * codes.words().len());
        self.scratch.extend(labels.iter().map(|&l| l as u8));
        for &word in codes.words() {
            self.scratch.extend_from_slice(&word.to_le_bytes());
        }
        let raw_len = self.scratch.len() as u64;
        let stored: &[u8] = if self.meta.compressed {
            codec::compress(&self.scratch, &mut self.comp);
            &self.comp
        } else {
            &self.scratch
        };
        let stored_len = stored.len() as u64;
        let mut sum = Fnv1a::new();
        sum.update(&rows.to_le_bytes());
        sum.update(stored);
        let checksum = sum.finish();
        match faults::trigger(faults::site::CACHE_WRITE_RECORD) {
            None => {}
            Some(faults::Injected::Error) => {
                return Err(faults::injected_error(faults::site::CACHE_WRITE_RECORD));
            }
            Some(faults::Injected::PartialWrite) => {
                // a torn write: the framing plus half the payload land on
                // disk, then the writer dies — exactly what a crash between
                // write() calls leaves behind
                self.out.write_all(&rows.to_le_bytes())?;
                self.out.write_all(&stored_len.to_le_bytes())?;
                self.out.write_all(&stored[..stored.len() / 2])?;
                self.out.flush()?;
                return Err(faults::injected_error(faults::site::CACHE_WRITE_RECORD));
            }
        }
        self.out.write_all(&rows.to_le_bytes())?;
        self.out.write_all(&stored_len.to_le_bytes())?;
        self.out.write_all(stored)?;
        self.out.write_all(&checksum.to_le_bytes())?;
        self.index.push(ChunkIndexEntry { offset: self.offset, rows, checksum });
        self.offset += 4 + 8 + stored_len + 8;
        self.meta.n += codes.n as u64;
        self.meta.raw_bytes += raw_len;
        self.meta.stored_bytes += stored_len;
        Ok(())
    }

    /// Record a resume-journal entry: "the cache is consistent through
    /// `self.offset`, and ingest continues at input byte `input_offset`,
    /// line `next_line`".  Called by the preprocess pipeline after every
    /// block (including blocks that produced no record — those still
    /// advance the input cursor).  Every `sync_chunks` calls the data file
    /// and then the journal are flushed + fsync'd.  No-op for non-durable
    /// writers.
    pub fn mark_progress(&mut self, input_offset: u64, next_line: u64) -> Result<()> {
        let entry = JournalEntry {
            records: self.index.len() as u64,
            cache_offset: self.offset,
            n: self.meta.n,
            raw_bytes: self.meta.raw_bytes,
            stored_bytes: self.meta.stored_bytes,
            input_offset,
            next_line,
        };
        let d = match self.durable.as_mut() {
            Some(d) => d,
            None => return Ok(()),
        };
        d.journal.write_all(&entry.to_bytes())?;
        d.marks_since_sync += 1;
        if d.marks_since_sync >= d.sync_chunks {
            d.marks_since_sync = 0;
            // data before journal: an entry should never describe bytes
            // that have not at least been handed to the OS
            self.out.flush()?;
            atomic_file::sync_file(&d.tmp)?;
            d.journal.flush()?;
            atomic_file::sync_file(&d.journal_path)?;
        }
        Ok(())
    }

    /// Write the chunk-index footer, patch the header byte/row counts, and
    /// flush.  Idempotent; a cache that was never finalized (crash
    /// mid-write) is rejected by the reader.
    ///
    /// Durable writers ([`create_durable`](Self::create_durable)) then
    /// commit: fsync the tmp, atomically rename it onto the destination,
    /// fsync the parent directory, and delete the resume journal.
    pub fn finalize(&mut self) -> Result<()> {
        if self.finalized {
            return Ok(());
        }
        faults::fail(faults::site::CACHE_FINALIZE)?;
        // footer: one fixed-width entry per record, checksummed as a block
        let mut entries = Vec::with_capacity(self.index.len() * INDEX_ENTRY_BYTES as usize);
        for e in &self.index {
            entries.extend_from_slice(&e.offset.to_le_bytes());
            entries.extend_from_slice(&e.rows.to_le_bytes());
            entries.extend_from_slice(&e.checksum.to_le_bytes());
        }
        let mut sum = Fnv1a::new();
        sum.update(&entries);
        self.out.write_all(&entries)?;
        self.out.write_all(&self.offset.to_le_bytes())?;
        self.out.write_all(&(self.index.len() as u64).to_le_bytes())?;
        self.out.write_all(&sum.finish().to_le_bytes())?;
        self.out.write_all(TRAILER_MAGIC)?;
        // patch raw/stored/n (contiguous) in one seek+write
        self.out.seek(SeekFrom::Start(STATS_OFFSET_V3))?;
        for v in [self.meta.raw_bytes, self.meta.stored_bytes, self.meta.n] {
            self.out.write_all(&v.to_le_bytes())?;
        }
        self.out.seek(SeekFrom::End(0))?;
        self.out.flush()?;
        if let Some(d) = self.durable.take() {
            atomic_file::commit(&d.tmp, &d.dst)?;
            drop(d.journal);
            let _ = std::fs::remove_file(&d.journal_path);
        }
        self.finalized = true;
        Ok(())
    }
}

/// Parse a v1/v2/v3 header from the current stream position, returning
/// the metadata and the on-disk version.
fn read_header<R: Read>(inner: &mut R) -> Result<(CacheMeta, u32)> {
    let mut magic = [0u8; 4];
    inner.read_exact(&mut magic)?;
    if &magic != CACHE_MAGIC {
        return Err(Error::InvalidArg("bad cache magic (not a BBHC file)".into()));
    }
    let mut u32buf = [0u8; 4];
    let mut u64buf = [0u8; 8];
    let mut next_u32 = |r: &mut R| -> Result<u32> {
        r.read_exact(&mut u32buf)?;
        Ok(u32::from_le_bytes(u32buf))
    };
    let mut next_u64 = |r: &mut R| -> Result<u64> {
        r.read_exact(&mut u64buf)?;
        Ok(u64::from_le_bytes(u64buf))
    };
    let version = next_u32(inner)?;
    let (spec, n, flags, raw_bytes, stored_bytes) = match version {
        // v1: fixed b-bit header {b, k, d, seed}
        1 => {
            let b = next_u32(inner)?;
            let k = next_u64(inner)? as usize;
            let d = next_u64(inner)?;
            let seed = next_u64(inner)?;
            let n = next_u64(inner)?;
            (EncoderSpec::Bbit { b, k, d, seed }, n, 0, 0, 0)
        }
        // v2: scheme-tagged EncoderSpec
        // v3: v2 + flags + payload byte totals (and an index footer the
        //     sequential reader never visits)
        2 | 3 => {
            let tag = next_u32(inner)?;
            let p0 = next_u32(inner)?;
            let p1 = next_u64(inner)?;
            let p2 = next_u64(inner)?;
            let seed = next_u64(inner)?;
            let (flags, raw, stored) = if version == 3 {
                (next_u32(inner)?, next_u64(inner)?, next_u64(inner)?)
            } else {
                (0, 0, 0)
            };
            let n = next_u64(inner)?;
            (EncoderSpec::from_header_fields(tag, p0, p1, p2, seed)?, n, flags, raw, stored)
        }
        v => {
            return Err(Error::InvalidArg(format!(
                "unsupported cache version {v} (expected {CACHE_VERSION_MIN}..={CACHE_VERSION})"
            )))
        }
    };
    if flags & !CACHE_FLAG_COMPRESSED != 0 {
        return Err(Error::InvalidArg(format!(
            "cache uses unknown feature flags {flags:#x} (newer writer?)"
        )));
    }
    spec.validate()
        .map_err(|e| Error::InvalidArg(format!("corrupt cache header: {e}")))?;
    if n == N_UNFINALIZED {
        return Err(Error::InvalidArg(
            "cache was never finalized (writer crashed mid-write?)".into(),
        ));
    }
    let meta = CacheMeta {
        spec,
        n,
        compressed: flags & CACHE_FLAG_COMPRESSED != 0,
        raw_bytes,
        stored_bytes,
    };
    Ok((meta, version))
}

/// Record decode engine shared by the sequential and the indexed readers:
/// owns the reusable payload/decompression scratch so replaying a cache
/// allocates nothing per record.
struct RecordDecoder {
    b: u32,
    k: usize,
    stride: usize,
    compressed: bool,
    /// On-disk payload scratch (compressed or raw).
    payload: Vec<u8>,
    /// Decompressed payload scratch (compressed caches only).
    raw: Vec<u8>,
}

impl RecordDecoder {
    fn for_meta(meta: &CacheMeta) -> Result<Self> {
        let (b, k, stride) = packed_geometry(&meta.spec)?;
        Ok(RecordDecoder {
            b,
            k,
            stride,
            compressed: meta.compressed,
            payload: Vec::new(),
            raw: Vec::new(),
        })
    }

    /// Read + verify one record from `r` into the caller's scratch
    /// buffers.  `row0` is the record's first global row (for error
    /// context), `rows_cap` the most rows this record may legally carry.
    /// Returns (rows decoded, the record's stored checksum).
    fn read_from<R: Read>(
        &mut self,
        r: &mut R,
        row0: u64,
        rows_cap: u64,
        codes: &mut PackedCodes,
        labels: &mut Vec<i8>,
    ) -> Result<(usize, u64)> {
        faults::fail(faults::site::REPLAY_DECODE)?;
        if codes.b != self.b || codes.k != self.k {
            return Err(Error::InvalidArg(format!(
                "scratch geometry (b={}, k={}) does not match cache (b={}, k={})",
                codes.b, codes.k, self.b, self.k
            )));
        }
        let mut u32buf = [0u8; 4];
        let mut u64buf = [0u8; 8];
        r.read_exact(&mut u32buf)?;
        let rows = u32::from_le_bytes(u32buf) as usize;
        r.read_exact(&mut u64buf)?;
        let stored_len = u64::from_le_bytes(u64buf);
        if rows as u64 > rows_cap {
            return Err(Error::InvalidArg(format!(
                "cache records overrun header count ({row0} + {rows} > {})",
                row0 + rows_cap
            )));
        }
        let raw_expect = rows as u64 + 8 * rows as u64 * self.stride as u64;
        let len_ok = if self.compressed {
            stored_len <= codec::max_compressed_len(raw_expect)
        } else {
            stored_len == raw_expect
        };
        if rows == 0 || !len_ok {
            return Err(Error::InvalidArg(format!(
                "corrupt cache record at row {row0}: {rows} rows, stored payload {stored_len} \
                 (raw size {raw_expect})"
            )));
        }
        self.payload.clear();
        self.payload.resize(stored_len as usize, 0);
        r.read_exact(&mut self.payload)?;
        let mut sum = Fnv1a::new();
        sum.update(&u32buf);
        sum.update(&self.payload);
        r.read_exact(&mut u64buf)?;
        let stored_sum = u64::from_le_bytes(u64buf);
        if stored_sum != sum.finish() {
            return Err(Error::InvalidArg(format!(
                "cache checksum mismatch at row {row0} (stored {stored_sum:#018x}, computed {:#018x})",
                sum.finish()
            )));
        }
        let raw: &[u8] = if self.compressed {
            codec::decompress(&self.payload, &mut self.raw, raw_expect as usize)?;
            &self.raw
        } else {
            &self.payload
        };
        labels.clear();
        labels.extend(raw[..rows].iter().map(|&v| v as i8));
        codes.fill_from_le_bytes(rows, &raw[rows..])?;
        Ok((rows, stored_sum))
    }
}

/// Sequential cache reader: header up front (v1, v2 or v3), then one chunk
/// per [`next_chunk_into`](Self::next_chunk_into) call with checksum
/// verification — constant memory regardless of corpus size, zero
/// allocation per record on the scratch-reuse path.
pub struct CacheReader<R: Read> {
    inner: R,
    meta: CacheMeta,
    decoder: RecordDecoder,
    rows_read: u64,
    poisoned: bool,
}

impl CacheReader<BufReader<File>> {
    pub fn open<P: AsRef<Path>>(path: P) -> Result<Self> {
        CacheReader::new(BufReader::with_capacity(1 << 20, File::open(path)?))
    }
}

impl<R: Read> CacheReader<R> {
    pub fn new(mut inner: R) -> Result<Self> {
        let (meta, _version) = read_header(&mut inner)?;
        let decoder = RecordDecoder::for_meta(&meta)?;
        Ok(CacheReader { inner, meta, decoder, rows_read: 0, poisoned: false })
    }

    /// The encoder recipe + row count from the header.
    pub fn meta(&self) -> CacheMeta {
        self.meta
    }

    /// Read and verify the next chunk record into the caller's reusable
    /// scratch buffers (`codes` keeps the cache's (b, k) geometry across
    /// calls; both buffers are overwritten).  Returns `false` once all
    /// `meta.n` rows have been replayed — the zero-alloc replay hot path.
    pub fn next_chunk_into(
        &mut self,
        codes: &mut PackedCodes,
        labels: &mut Vec<i8>,
    ) -> Result<bool> {
        if self.poisoned {
            return Err(Error::InvalidArg("cache reader poisoned by earlier error".into()));
        }
        if self.rows_read >= self.meta.n {
            return Ok(false);
        }
        match self.decoder.read_from(
            &mut self.inner,
            self.rows_read,
            self.meta.n - self.rows_read,
            codes,
            labels,
        ) {
            Ok((rows, _)) => {
                self.rows_read += rows as u64;
                Ok(true)
            }
            Err(e) => {
                self.poisoned = true;
                Err(e)
            }
        }
    }

    /// Allocating form of [`next_chunk_into`](Self::next_chunk_into):
    /// `None` once all `meta.n` rows have been replayed.
    pub fn next_chunk(&mut self) -> Result<Option<(PackedCodes, Vec<i8>)>> {
        let mut codes = PackedCodes::new(self.decoder.b, self.decoder.k);
        let mut labels = Vec::new();
        if self.next_chunk_into(&mut codes, &mut labels)? {
            Ok(Some((codes, labels)))
        } else {
            Ok(None)
        }
    }

    /// Materialize the whole cache (small inputs / batch solvers; the
    /// streaming trainer never calls this).  Buffers are pre-sized from
    /// the header's row count and filled through the scratch-reuse path.
    pub fn read_all(mut self) -> Result<BbitDataset> {
        let n = self.meta.n as usize;
        let mut all = PackedCodes::new(self.decoder.b, self.decoder.k);
        all.reserve_rows(n);
        let mut all_labels: Vec<i8> = Vec::with_capacity(n);
        let mut codes = PackedCodes::new(self.decoder.b, self.decoder.k);
        let mut labels = Vec::new();
        while self.next_chunk_into(&mut codes, &mut labels)? {
            all.extend(&codes)?;
            all_labels.extend_from_slice(&labels);
        }
        Ok(BbitDataset::new(all, all_labels))
    }
}

impl<R: Read> Iterator for CacheReader<R> {
    type Item = Result<(PackedCodes, Vec<i8>)>;

    fn next(&mut self) -> Option<Self::Item> {
        self.next_chunk().transpose()
    }
}

/// One chunk-index footer entry: where a record lives and what it holds.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct ChunkIndexEntry {
    /// Absolute byte offset of the record (its `rows` field).
    pub offset: u64,
    /// Rows in the record.
    pub rows: u32,
    /// The record's FNV-1a checksum (== the one stored inline after the
    /// payload) — an indexed reader can verify without trusting the seek.
    pub checksum: u64,
}

/// The parsed v3 chunk-index footer: the record map that makes a cache
/// partitionable without a pre-scan.
#[derive(Clone, Debug)]
pub struct ChunkIndex {
    /// One entry per record, in file (= replay) order.
    pub entries: Vec<ChunkIndexEntry>,
    /// Byte offset one past the last record (= where the footer starts) —
    /// the exact length of the header + record stream.
    pub records_end: u64,
}

impl ChunkIndex {
    /// Total rows across all indexed records.
    pub fn rows_total(&self) -> u64 {
        self.entries.iter().map(|e| e.rows as u64).sum()
    }

    /// Global first-row index of each record (exclusive prefix sums) —
    /// what deterministic per-row consumers (holdout splits) key on.
    pub fn row_starts(&self) -> Vec<u64> {
        let mut starts = Vec::with_capacity(self.entries.len());
        let mut row = 0u64;
        for e in &self.entries {
            starts.push(row);
            row += e.rows as u64;
        }
        starts
    }

    /// Load the footer of a cache file.  `Ok(None)` means the file is
    /// valid but has no usable index — pre-v3 version, or a truncated /
    /// corrupt footer (callers fall back to the sequential scan); hard IO
    /// and header errors stay `Err`.
    pub fn load<P: AsRef<Path>>(path: P) -> Result<Option<ChunkIndex>> {
        ChunkIndex::from_reader(&mut File::open(path)?)
    }

    /// [`load`](Self::load) over any seekable stream (tests use an
    /// in-memory cursor).
    pub fn from_reader<R: Read + Seek>(r: &mut R) -> Result<Option<ChunkIndex>> {
        r.seek(SeekFrom::Start(0))?;
        let (meta, version) = read_header(r)?;
        if version < 3 {
            return Ok(None);
        }
        let len = r.seek(SeekFrom::End(0))?;
        if len < HEADER_BYTES_V3 + TRAILER_BYTES {
            return Ok(None);
        }
        r.seek(SeekFrom::Start(len - TRAILER_BYTES))?;
        let mut trailer = [0u8; TRAILER_BYTES as usize];
        r.read_exact(&mut trailer)?;
        if &trailer[24..32] != TRAILER_MAGIC {
            return Ok(None);
        }
        let index_off = u64::from_le_bytes(trailer[0..8].try_into().unwrap());
        let count = u64::from_le_bytes(trailer[8..16].try_into().unwrap());
        let stored_sum = u64::from_le_bytes(trailer[16..24].try_into().unwrap());
        // bound both fields before any arithmetic: a corrupt trailer with
        // a huge offset/count must downgrade to "no index", never overflow
        let max_index_off = len - TRAILER_BYTES;
        if index_off < HEADER_BYTES_V3
            || index_off > max_index_off
            || count > len / INDEX_ENTRY_BYTES
            || count * INDEX_ENTRY_BYTES != max_index_off - index_off
        {
            return Ok(None);
        }
        r.seek(SeekFrom::Start(index_off))?;
        let mut bytes = vec![0u8; (count * INDEX_ENTRY_BYTES) as usize];
        r.read_exact(&mut bytes)?;
        let mut sum = Fnv1a::new();
        sum.update(&bytes);
        if sum.finish() != stored_sum {
            return Ok(None);
        }
        let mut entries = Vec::with_capacity(count as usize);
        let mut rows_total = 0u64;
        for (i, chunk) in bytes.chunks_exact(INDEX_ENTRY_BYTES as usize).enumerate() {
            let entry = ChunkIndexEntry {
                offset: u64::from_le_bytes(chunk[0..8].try_into().unwrap()),
                rows: u32::from_le_bytes(chunk[8..12].try_into().unwrap()),
                checksum: u64::from_le_bytes(chunk[12..20].try_into().unwrap()),
            };
            // entries must march left to right through the record region:
            // the first starts right after the header, each later one past
            // its predecessor's minimal extent (12-byte framing + ≥ 1
            // payload byte + 8-byte checksum), and all before the footer
            let min_start = match entries.last() {
                None => HEADER_BYTES_V3,
                Some(prev) => prev.offset + 4 + 8 + 1 + 8,
            };
            let first_bad = i == 0 && entry.offset != HEADER_BYTES_V3;
            if first_bad || entry.offset < min_start || entry.offset >= index_off || entry.rows == 0
            {
                return Ok(None);
            }
            rows_total += entry.rows as u64;
            entries.push(entry);
        }
        // final sanity: the index must account for exactly the header's rows
        if rows_total != meta.n {
            return Ok(None);
        }
        Ok(Some(ChunkIndex { entries, records_end: index_off }))
    }
}

/// Random-access record reader over an indexed cache: seek to any
/// [`ChunkIndexEntry`] and decode it into reusable scratch — one of these
/// per reader-pool thread.
pub struct IndexedCacheReader<R: Read + Seek> {
    inner: R,
    meta: CacheMeta,
    decoder: RecordDecoder,
}

impl IndexedCacheReader<File> {
    /// Open a per-thread handle (unbuffered: access is one seek + three
    /// reads per record, dominated by the payload read).
    pub fn open<P: AsRef<Path>>(path: P) -> Result<Self> {
        IndexedCacheReader::new(File::open(path)?)
    }
}

impl<R: Read + Seek> IndexedCacheReader<R> {
    pub fn new(mut inner: R) -> Result<Self> {
        inner.seek(SeekFrom::Start(0))?;
        let (meta, _version) = read_header(&mut inner)?;
        let decoder = RecordDecoder::for_meta(&meta)?;
        Ok(IndexedCacheReader { inner, meta, decoder })
    }

    pub fn meta(&self) -> CacheMeta {
        self.meta
    }

    /// Decode the record `entry` describes into the caller's scratch
    /// buffers, verifying both the inline checksum and the index entry
    /// (`row0` is the record's global first row, for error context).
    pub fn read_into(
        &mut self,
        entry: &ChunkIndexEntry,
        row0: u64,
        codes: &mut PackedCodes,
        labels: &mut Vec<i8>,
    ) -> Result<()> {
        self.inner.seek(SeekFrom::Start(entry.offset))?;
        let (rows, checksum) =
            self.decoder
                .read_from(&mut self.inner, row0, entry.rows as u64, codes, labels)?;
        if rows as u32 != entry.rows || checksum != entry.checksum {
            return Err(Error::InvalidArg(format!(
                "cache record at row {row0} disagrees with its index entry \
                 ({rows} rows vs {}, checksum {checksum:#018x} vs {:#018x})",
                entry.rows, entry.checksum
            )));
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::Rng;
    use std::io::Cursor;

    fn random_chunk(b: u32, k: usize, rows: usize, rng: &mut Rng) -> (PackedCodes, Vec<i8>) {
        let mut pc = PackedCodes::new(b, k);
        let mut labels = Vec::with_capacity(rows);
        for _ in 0..rows {
            let row: Vec<u16> = (0..k).map(|_| rng.below(1 << b) as u16).collect();
            pc.push_row(&row).unwrap();
            labels.push(if rng.bool() { 1 } else { -1 });
        }
        (pc, labels)
    }

    fn bbit_spec(b: u32, k: usize, d: u64, seed: u64) -> EncoderSpec {
        EncoderSpec::Bbit { b, k, d, seed }
    }

    /// Property-style roundtrip over geometries and ragged chunk sizes,
    /// with the v3 index footer verified against the record stream.
    #[test]
    fn roundtrip_random_geometries() {
        let mut rng = Rng::new(0xCAFE);
        for &(b, k) in &[(1u32, 64usize), (7, 33), (8, 200), (12, 37), (16, 5)] {
            let sizes = [1usize, 17, 256, 3];
            let mut buf = Cursor::new(Vec::new());
            let spec = bbit_spec(b, k, 1 << 30, 42);
            let mut w = CacheWriter::new(&mut buf, &spec).unwrap();
            let mut chunks = Vec::new();
            for &rows in &sizes {
                let (pc, ls) = random_chunk(b, k, rows, &mut rng);
                w.write_chunk(&pc, &ls).unwrap();
                chunks.push((pc, ls));
            }
            w.finalize().unwrap();
            w.finalize().unwrap(); // idempotent
            buf.set_position(0);
            let mut r = CacheReader::new(&mut buf).unwrap();
            let meta = r.meta();
            let n: u64 = sizes.iter().sum::<usize>() as u64;
            let stride = (k * b as usize).div_ceil(64);
            let payload: u64 = sizes.iter().map(|&s| (s + 8 * s * stride) as u64).sum();
            assert_eq!(meta.spec, spec);
            assert_eq!(meta.n, n);
            assert!(!meta.compressed);
            assert_eq!(meta.raw_bytes, payload, "b={b} k={k}");
            assert_eq!(meta.stored_bytes, payload);
            for (pc, ls) in &chunks {
                let (got_pc, got_ls) = r.next_chunk().unwrap().unwrap();
                assert_eq!(&got_pc, pc, "b={b} k={k}");
                assert_eq!(&got_ls, ls);
            }
            assert!(r.next_chunk().unwrap().is_none());
            assert!(r.next_chunk().unwrap().is_none()); // fused

            // the index footer addresses every record, in order
            let mut buf2 = Cursor::new(buf.get_ref().clone());
            let index = ChunkIndex::from_reader(&mut buf2).unwrap().expect("v3 has an index");
            assert_eq!(index.entries.len(), sizes.len());
            assert_eq!(index.rows_total(), n);
            assert_eq!(
                index.row_starts(),
                vec![0u64, 1, 18, 274],
                "prefix sums over {sizes:?}"
            );
            // random-access reads reproduce the sequential chunks — in
            // reverse order, to prove seeks are honest
            let mut ir = IndexedCacheReader::new(&mut buf2).unwrap();
            let starts = index.row_starts();
            let mut codes = PackedCodes::new(b, k);
            let mut labels = Vec::new();
            for rec in (0..index.entries.len()).rev() {
                ir.read_into(&index.entries[rec], starts[rec], &mut codes, &mut labels)
                    .unwrap();
                assert_eq!(codes, chunks[rec].0, "record {rec}");
                assert_eq!(labels, chunks[rec].1);
            }
        }
    }

    #[test]
    fn next_chunk_into_reuses_scratch_and_matches_next_chunk() {
        let mut rng = Rng::new(0x5C4A);
        let spec = bbit_spec(5, 19, 1 << 20, 8);
        let mut buf = Cursor::new(Vec::new());
        let mut w = CacheWriter::new(&mut buf, &spec).unwrap();
        let mut chunks = Vec::new();
        for rows in [7usize, 64, 3, 31] {
            let (pc, ls) = random_chunk(5, 19, rows, &mut rng);
            w.write_chunk(&pc, &ls).unwrap();
            chunks.push((pc, ls));
        }
        w.finalize().unwrap();
        buf.set_position(0);
        let mut r = CacheReader::new(&mut buf).unwrap();
        let mut codes = PackedCodes::new(5, 19);
        let mut labels = Vec::new();
        for (pc, ls) in &chunks {
            assert!(r.next_chunk_into(&mut codes, &mut labels).unwrap());
            assert_eq!(&codes, pc);
            assert_eq!(&labels, ls);
        }
        assert!(!r.next_chunk_into(&mut codes, &mut labels).unwrap());
        // wrong-geometry scratch is a typed error, not silent corruption
        buf.set_position(0);
        let mut r = CacheReader::new(&mut buf).unwrap();
        let mut bad = PackedCodes::new(5, 20);
        assert!(r.next_chunk_into(&mut bad, &mut labels).is_err());
    }

    #[test]
    fn compressed_cache_roundtrips_and_reports_byte_totals() {
        let spec = bbit_spec(8, 24, 1 << 20, 4);
        let mut buf = Cursor::new(Vec::new());
        let mut w = CacheWriter::with_options(
            &mut buf,
            &spec,
            CacheWriteOptions { compress: true },
        )
        .unwrap();
        // constant rows → long byte runs → real compression
        let mut pc = PackedCodes::new(8, 24);
        for _ in 0..50 {
            pc.push_row(&[3u16; 24]).unwrap();
        }
        let labels = vec![1i8; 50];
        w.write_chunk(&pc, &labels).unwrap();
        // plus an incompressible chunk (still must roundtrip)
        let (noise, noise_ls) = random_chunk(8, 24, 40, &mut Rng::new(77));
        w.write_chunk(&noise, &noise_ls).unwrap();
        w.finalize().unwrap();
        buf.set_position(0);
        let mut r = CacheReader::new(&mut buf).unwrap();
        let meta = r.meta();
        assert!(meta.compressed);
        assert_eq!(meta.n, 90);
        assert!(
            meta.stored_bytes < meta.raw_bytes,
            "constant chunk must compress: stored {} raw {}",
            meta.stored_bytes,
            meta.raw_bytes
        );
        let (got, ls) = r.next_chunk().unwrap().unwrap();
        assert_eq!(got, pc);
        assert_eq!(ls, labels);
        let (got, ls) = r.next_chunk().unwrap().unwrap();
        assert_eq!(got, noise);
        assert_eq!(ls, noise_ls);
        assert!(r.next_chunk().unwrap().is_none());
        // the index addresses compressed records just the same
        let mut buf2 = Cursor::new(buf.get_ref().clone());
        let index = ChunkIndex::from_reader(&mut buf2).unwrap().unwrap();
        assert_eq!(index.entries.len(), 2);
        let mut ir = IndexedCacheReader::new(&mut buf2).unwrap();
        let mut codes = PackedCodes::new(8, 24);
        let mut labs = Vec::new();
        ir.read_into(&index.entries[1], 50, &mut codes, &mut labs).unwrap();
        assert_eq!(codes, noise);
    }

    #[test]
    fn oph_spec_roundtrips_through_header() {
        let mut rng = Rng::new(0x0F4);
        let spec = EncoderSpec::Oph { bins: 24, b: 6, seed: 9 };
        let mut buf = Cursor::new(Vec::new());
        let mut w = CacheWriter::new(&mut buf, &spec).unwrap();
        let (pc, ls) = random_chunk(6, 24, 11, &mut rng);
        w.write_chunk(&pc, &ls).unwrap();
        w.finalize().unwrap();
        buf.set_position(0);
        let mut r = CacheReader::new(&mut buf).unwrap();
        assert_eq!(r.meta().spec, spec);
        assert_eq!(r.meta().n, 11);
        assert_eq!(r.meta().expanded_dim(), (1 << 6) * 24);
        let (got, _) = r.next_chunk().unwrap().unwrap();
        assert_eq!(got, pc);
    }

    #[test]
    fn sparse_specs_are_rejected_by_writer() {
        let buf = Cursor::new(Vec::new());
        assert!(CacheWriter::new(buf, &EncoderSpec::Vw { bins: 64, seed: 1 }).is_err());
        let buf = Cursor::new(Vec::new());
        assert!(CacheWriter::new(buf, &EncoderSpec::Rp { proj: 64, s: 1.0, seed: 1 }).is_err());
    }

    /// Hand-written v1 bytes must keep parsing as EncoderSpec::Bbit.
    #[test]
    fn v1_cache_is_still_readable() {
        let (b, k, d, seed) = (8u32, 16usize, 1u64 << 20, 7u64);
        let mut rng = Rng::new(0x01d);
        let (pc, ls) = random_chunk(b, k, 5, &mut rng);
        let mut bytes = Vec::new();
        bytes.extend_from_slice(CACHE_MAGIC);
        bytes.extend_from_slice(&1u32.to_le_bytes()); // version 1
        bytes.extend_from_slice(&b.to_le_bytes());
        for v in [k as u64, d, seed, 5u64] {
            bytes.extend_from_slice(&v.to_le_bytes());
        }
        // one v1 record (same record format as v2/v3-uncompressed)
        let stride = (k * b as usize).div_ceil(64);
        let rows = 5u32;
        let mut payload = Vec::new();
        payload.extend(ls.iter().map(|&l| l as u8));
        for &word in pc.words() {
            payload.extend_from_slice(&word.to_le_bytes());
        }
        assert_eq!(payload.len(), 5 + 8 * 5 * stride);
        let mut sum = Fnv1a::new();
        sum.update(&rows.to_le_bytes());
        sum.update(&payload);
        bytes.extend_from_slice(&rows.to_le_bytes());
        bytes.extend_from_slice(&(payload.len() as u64).to_le_bytes());
        bytes.extend_from_slice(&payload);
        bytes.extend_from_slice(&sum.finish().to_le_bytes());

        let mut r = CacheReader::new(Cursor::new(bytes.clone())).unwrap();
        assert_eq!(r.meta().spec, EncoderSpec::Bbit { b, k, d, seed });
        assert_eq!(r.meta().n, 5);
        assert!(!r.meta().compressed);
        assert_eq!(r.meta().raw_bytes, 0, "pre-v3 headers carry no byte totals");
        let (got_pc, got_ls) = r.next_chunk().unwrap().unwrap();
        assert_eq!(got_pc, pc);
        assert_eq!(got_ls, ls);
        assert!(r.next_chunk().unwrap().is_none());
        // no footer → no index, but not an error either
        assert!(ChunkIndex::from_reader(&mut Cursor::new(bytes)).unwrap().is_none());
    }

    /// Hand-written v2 bytes (the pre-index header) keep parsing too.
    #[test]
    fn v2_cache_is_still_readable() {
        let spec = EncoderSpec::Oph { bins: 16, b: 4, seed: 3 };
        let (tag, p0, p1, p2, seed) = spec.header_fields();
        let mut rng = Rng::new(0x02d);
        let (pc, ls) = random_chunk(4, 16, 9, &mut rng);
        let mut bytes = Vec::new();
        bytes.extend_from_slice(CACHE_MAGIC);
        bytes.extend_from_slice(&2u32.to_le_bytes()); // version 2
        bytes.extend_from_slice(&tag.to_le_bytes());
        bytes.extend_from_slice(&p0.to_le_bytes());
        for v in [p1, p2, seed, 9u64] {
            bytes.extend_from_slice(&v.to_le_bytes());
        }
        let rows = 9u32;
        let mut payload = Vec::new();
        payload.extend(ls.iter().map(|&l| l as u8));
        for &word in pc.words() {
            payload.extend_from_slice(&word.to_le_bytes());
        }
        let mut sum = Fnv1a::new();
        sum.update(&rows.to_le_bytes());
        sum.update(&payload);
        bytes.extend_from_slice(&rows.to_le_bytes());
        bytes.extend_from_slice(&(payload.len() as u64).to_le_bytes());
        bytes.extend_from_slice(&payload);
        bytes.extend_from_slice(&sum.finish().to_le_bytes());

        let mut r = CacheReader::new(Cursor::new(bytes.clone())).unwrap();
        assert_eq!(r.meta().spec, spec);
        assert_eq!(r.meta().n, 9);
        let (got_pc, got_ls) = r.next_chunk().unwrap().unwrap();
        assert_eq!(got_pc, pc);
        assert_eq!(got_ls, ls);
        assert!(ChunkIndex::from_reader(&mut Cursor::new(bytes)).unwrap().is_none());
    }

    #[test]
    fn unknown_version_is_rejected() {
        let mut bytes = Vec::new();
        bytes.extend_from_slice(CACHE_MAGIC);
        bytes.extend_from_slice(&9u32.to_le_bytes()); // future version
        bytes.extend_from_slice(&[0u8; 64]);
        assert!(CacheReader::new(Cursor::new(bytes)).is_err());
    }

    #[test]
    fn unknown_flags_are_rejected() {
        let spec = bbit_spec(8, 16, 1 << 20, 7);
        let mut buf = Cursor::new(Vec::new());
        let mut w = CacheWriter::new(&mut buf, &spec).unwrap();
        w.finalize().unwrap();
        let mut bytes = buf.into_inner();
        // flags field lives right after the 40-byte spec prefix
        bytes[40] |= 0x80;
        let err = CacheReader::new(Cursor::new(bytes)).unwrap_err();
        assert!(err.to_string().contains("flags"), "{err}");
    }

    #[test]
    fn empty_cache_roundtrips() {
        let mut buf = Cursor::new(Vec::new());
        let mut w = CacheWriter::new(&mut buf, &bbit_spec(8, 16, 1 << 20, 7)).unwrap();
        let empty = PackedCodes::new(8, 16);
        w.write_chunk(&empty, &[]).unwrap(); // dropped, not an error
        w.finalize().unwrap();
        buf.set_position(0);
        let ds = CacheReader::new(&mut buf).unwrap().read_all().unwrap();
        assert_eq!(ds.len(), 0);
        let index = ChunkIndex::from_reader(&mut buf).unwrap().unwrap();
        assert!(index.entries.is_empty());
        assert_eq!(index.records_end, HEADER_BYTES_V3);
    }

    #[test]
    fn unfinalized_cache_is_rejected() {
        let mut buf = Cursor::new(Vec::new());
        let mut w = CacheWriter::new(&mut buf, &bbit_spec(8, 16, 1 << 20, 7)).unwrap();
        let (pc, ls) = random_chunk(8, 16, 5, &mut Rng::new(1));
        w.write_chunk(&pc, &ls).unwrap();
        // no finalize
        drop(w);
        buf.set_position(0);
        assert!(CacheReader::new(&mut buf).is_err());
    }

    #[test]
    fn corruption_is_detected() {
        let mut rng = Rng::new(9);
        let mut buf = Cursor::new(Vec::new());
        let mut w = CacheWriter::new(&mut buf, &bbit_spec(8, 32, 1 << 20, 3)).unwrap();
        let (pc, ls) = random_chunk(8, 32, 40, &mut rng);
        w.write_chunk(&pc, &ls).unwrap();
        w.finalize().unwrap();
        let mut bytes = buf.into_inner();
        // flip one payload byte past the record's 12-byte framing
        let target = HEADER_BYTES_V3 as usize + 12 + 7;
        bytes[target] ^= 0x40;
        let mut r = CacheReader::new(Cursor::new(bytes.clone())).unwrap();
        assert!(r.next_chunk().is_err());
        assert!(r.next_chunk().is_err()); // poisoned stays poisoned
        // the indexed reader rejects the same damage
        let mut cur = Cursor::new(bytes);
        let index = ChunkIndex::from_reader(&mut cur).unwrap().unwrap();
        let mut ir = IndexedCacheReader::new(&mut cur).unwrap();
        let mut codes = PackedCodes::new(8, 32);
        let mut labs = Vec::new();
        assert!(ir.read_into(&index.entries[0], 0, &mut codes, &mut labs).is_err());
    }

    #[test]
    fn truncated_cache_is_detected() {
        let mut buf = Cursor::new(Vec::new());
        let mut w = CacheWriter::new(&mut buf, &bbit_spec(4, 8, 1 << 16, 1)).unwrap();
        let (pc, ls) = random_chunk(4, 8, 10, &mut Rng::new(2));
        w.write_chunk(&pc, &ls).unwrap();
        w.finalize().unwrap();
        let bytes = buf.into_inner();
        let records_end = ChunkIndex::from_reader(&mut Cursor::new(bytes.clone()))
            .unwrap()
            .unwrap()
            .records_end as usize;
        // lose the footer and the tail of the final record
        let cut = &bytes[..records_end - 9];
        let mut r = CacheReader::new(Cursor::new(cut.to_vec())).unwrap();
        assert!(r.next_chunk().is_err());
    }

    /// A damaged or missing footer downgrades to "no index" — the record
    /// stream stays fully replayable.
    #[test]
    fn truncated_footer_disables_the_index_not_the_cache() {
        let mut rng = Rng::new(0xF007);
        let spec = bbit_spec(6, 20, 1 << 20, 2);
        let mut buf = Cursor::new(Vec::new());
        let mut w = CacheWriter::new(&mut buf, &spec).unwrap();
        let mut chunks = Vec::new();
        for rows in [13usize, 40, 8] {
            let (pc, ls) = random_chunk(6, 20, rows, &mut rng);
            w.write_chunk(&pc, &ls).unwrap();
            chunks.push((pc, ls));
        }
        w.finalize().unwrap();
        let bytes = buf.into_inner();
        let records_end = ChunkIndex::from_reader(&mut Cursor::new(bytes.clone()))
            .unwrap()
            .unwrap()
            .records_end as usize;
        for cut in [
            records_end,                    // footer gone entirely
            bytes.len() - 3,                // trailer torn
            bytes.len() - TRAILER_BYTES as usize - 5, // entries torn
        ] {
            let mut cur = Cursor::new(bytes[..cut].to_vec());
            assert!(
                ChunkIndex::from_reader(&mut cur).unwrap().is_none(),
                "cut at {cut} must yield no index"
            );
            let mut r = CacheReader::new(Cursor::new(bytes[..cut].to_vec())).unwrap();
            for (pc, ls) in &chunks {
                let (got_pc, got_ls) = r.next_chunk().unwrap().unwrap();
                assert_eq!(&got_pc, pc);
                assert_eq!(&got_ls, ls);
            }
            assert!(r.next_chunk().unwrap().is_none());
        }
        // a flipped byte inside the entries fails the footer checksum
        let mut bad = bytes.clone();
        bad[records_end + 2] ^= 0x10;
        assert!(ChunkIndex::from_reader(&mut Cursor::new(bad)).unwrap().is_none());
        // a huge index offset in an otherwise intact trailer must
        // downgrade too — not overflow the bounds arithmetic
        let mut bad = bytes.clone();
        let trailer_at = bytes.len() - TRAILER_BYTES as usize;
        bad[trailer_at..trailer_at + 8].copy_from_slice(&u64::MAX.to_le_bytes());
        assert!(ChunkIndex::from_reader(&mut Cursor::new(bad)).unwrap().is_none());
        // ... and so must a huge record count
        let mut bad = bytes;
        bad[trailer_at + 8..trailer_at + 16].copy_from_slice(&u64::MAX.to_le_bytes());
        assert!(ChunkIndex::from_reader(&mut Cursor::new(bad)).unwrap().is_none());
    }

    #[test]
    fn geometry_mismatch_rejected_by_writer() {
        let mut buf = Cursor::new(Vec::new());
        let mut w = CacheWriter::new(&mut buf, &bbit_spec(8, 16, 1 << 20, 7)).unwrap();
        let (pc, ls) = random_chunk(8, 17, 3, &mut Rng::new(3));
        assert!(w.write_chunk(&pc, &ls).is_err());
        let (pc, _) = random_chunk(8, 16, 3, &mut Rng::new(4));
        assert!(w.write_chunk(&pc, &[1, -1]).is_err()); // label count
    }

    // ---- durable (tmp/rename + resume journal) path ----

    fn durable_dir(name: &str) -> std::path::PathBuf {
        let d = std::env::temp_dir().join(format!("bbmh_cache_{}_{}", name, std::process::id()));
        let _ = std::fs::remove_dir_all(&d);
        std::fs::create_dir_all(&d).unwrap();
        d
    }

    fn fixed_chunks(count: usize, seed: u64) -> Vec<(PackedCodes, Vec<i8>)> {
        let mut rng = Rng::new(seed);
        (0..count).map(|i| random_chunk(6, 20, 5 + i, &mut rng)).collect()
    }

    #[test]
    fn durable_writer_commits_atomically_and_matches_plain_bytes() {
        let d = durable_dir("commit");
        let dst = d.join("out.cache");
        let spec = bbit_spec(6, 20, 1 << 20, 11);
        let chunks = fixed_chunks(4, 0xD0C5);

        let mut w =
            CacheWriter::create_durable(&dst, &spec, CacheWriteOptions::default(), 2).unwrap();
        for (i, (pc, ls)) in chunks.iter().enumerate() {
            w.write_chunk(pc, ls).unwrap();
            w.mark_progress(100 * (i as u64 + 1), i as u64 + 2).unwrap();
        }
        // mid-run: destination absent, tmp + journal present
        assert!(!dst.exists());
        assert!(atomic_file::tmp_path(&dst).exists());
        assert!(journal_path(&dst).exists());
        w.finalize().unwrap();
        assert!(dst.exists());
        assert!(!atomic_file::tmp_path(&dst).exists());
        assert!(!journal_path(&dst).exists());

        // byte-for-byte the same file a plain in-memory writer produces
        let mut cur = Cursor::new(Vec::new());
        let mut pw = CacheWriter::new(&mut cur, &spec).unwrap();
        for (pc, ls) in &chunks {
            pw.write_chunk(pc, ls).unwrap();
        }
        pw.finalize().unwrap();
        assert_eq!(std::fs::read(&dst).unwrap(), *cur.get_ref());
    }

    #[test]
    fn resume_recovers_torn_tail_to_byte_identical_cache() {
        let d = durable_dir("resume");
        let spec = bbit_spec(6, 20, 1 << 20, 11);
        let chunks = fixed_chunks(5, 0xBEEF);

        // reference: uninterrupted durable run over all five chunks
        let ref_dst = d.join("ref.cache");
        let mut w =
            CacheWriter::create_durable(&ref_dst, &spec, CacheWriteOptions::default(), 1).unwrap();
        for (i, (pc, ls)) in chunks.iter().enumerate() {
            w.write_chunk(pc, ls).unwrap();
            w.mark_progress(100 * (i as u64 + 1), 10 * (i as u64 + 1)).unwrap();
        }
        w.finalize().unwrap();

        // crashed run: three chunks journaled, then a torn fourth record
        let dst = d.join("out.cache");
        let mut w =
            CacheWriter::create_durable(&dst, &spec, CacheWriteOptions::default(), 1).unwrap();
        for (i, (pc, ls)) in chunks.iter().take(3).enumerate() {
            w.write_chunk(pc, ls).unwrap();
            w.mark_progress(100 * (i as u64 + 1), 10 * (i as u64 + 1)).unwrap();
        }
        drop(w); // crash: no finalize; BufWriter flushes what it has
        let tmp = atomic_file::tmp_path(&dst);
        {
            use std::io::Write as _;
            let mut f = OpenOptions::new().append(true).open(&tmp).unwrap();
            // half a record's framing: rows + length, payload missing
            f.write_all(&7u32.to_le_bytes()).unwrap();
            f.write_all(&999u64.to_le_bytes()).unwrap();
            f.write_all(&[0xAB; 40]).unwrap();
        }

        let (mut w, point) =
            CacheWriter::resume_durable(&dst, &spec, CacheWriteOptions::default(), 1)
                .unwrap()
                .expect("leftovers should be resumable");
        assert_eq!(point.records, 3);
        assert_eq!(point.rows, (5 + 6 + 7) as u64);
        assert_eq!(point.input_offset, 300);
        assert_eq!(point.next_line, 30);
        for (i, (pc, ls)) in chunks.iter().enumerate().skip(3) {
            w.write_chunk(pc, ls).unwrap();
            w.mark_progress(100 * (i as u64 + 1), 10 * (i as u64 + 1)).unwrap();
        }
        w.finalize().unwrap();
        assert_eq!(
            std::fs::read(&dst).unwrap(),
            std::fs::read(&ref_dst).unwrap(),
            "resumed cache must be byte-identical to the uninterrupted run"
        );
        assert!(!tmp.exists());
        assert!(!journal_path(&dst).exists());
    }

    #[test]
    fn resume_with_unjournaled_tail_reingests_from_last_mark() {
        let d = durable_dir("tail");
        let spec = bbit_spec(6, 20, 1 << 20, 11);
        let chunks = fixed_chunks(4, 0x7A11);
        let dst = d.join("out.cache");
        // journal only the first two blocks; write (valid) chunks past them
        let mut w =
            CacheWriter::create_durable(&dst, &spec, CacheWriteOptions::default(), 1).unwrap();
        for (i, (pc, ls)) in chunks.iter().enumerate() {
            w.write_chunk(pc, ls).unwrap();
            if i < 2 {
                w.mark_progress(100 * (i as u64 + 1), 10 * (i as u64 + 1)).unwrap();
            }
        }
        drop(w);
        let (w, point) =
            CacheWriter::resume_durable(&dst, &spec, CacheWriteOptions::default(), 1)
                .unwrap()
                .expect("resumable");
        // valid-but-unjournaled records are discarded: input position for
        // them is unknown, so ingest restarts at the last journal mark
        assert_eq!(point.records, 2);
        assert_eq!(point.input_offset, 200);
        drop(w);
    }

    #[test]
    fn resume_without_leftovers_is_none_and_mismatches_are_typed() {
        let d = durable_dir("none");
        let dst = d.join("out.cache");
        let spec = bbit_spec(6, 20, 1 << 20, 11);
        assert!(CacheWriter::resume_durable(&dst, &spec, CacheWriteOptions::default(), 1)
            .unwrap()
            .is_none());

        // leftovers written under a different spec are a typed error
        let mut w =
            CacheWriter::create_durable(&dst, &spec, CacheWriteOptions::default(), 1).unwrap();
        let chunks = fixed_chunks(1, 1);
        let (pc, ls) = &chunks[0];
        w.write_chunk(pc, ls).unwrap();
        w.mark_progress(10, 2).unwrap();
        drop(w);
        let other = bbit_spec(6, 20, 1 << 20, 12);
        assert!(CacheWriter::resume_durable(&dst, &other, CacheWriteOptions::default(), 1)
            .is_err());
        let err = CacheWriter::resume_durable(
            &dst,
            &spec,
            CacheWriteOptions { compress: true },
            1,
        )
        .unwrap_err();
        assert!(err.to_string().contains("compression"), "{err}");
    }
}
