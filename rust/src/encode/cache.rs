//! On-disk hashed-dataset cache — hash a corpus once, train on it many
//! times.
//!
//! The paper's economics (Sections 1 and 6) hinge on preprocessing being a
//! one-time cost amortized over every (solver, C, b, k≤K) sweep that
//! follows; fwumious wabbit ships the same shape as its "input cache"
//! (scenario 1 of its BENCHMARK.md: generate the cache once, then run many
//! fast training passes over it).  This module is that artifact for packed
//! b-bit codes — whichever [`FeatureEncoder`](crate::encode::encoder)
//! scheme produced them (b-bit minwise, OPH, ...): a sequential,
//! checksummed record stream a 200GB-scale corpus can be written to and
//! replayed from in constant memory.
//!
//! ## Layout (all integers little-endian)
//!
//! v2 (current — written by every [`CacheWriter`]):
//!
//! ```text
//!   magic  b"BBHC"
//!   u32    format version (= 2)
//!   u32    scheme tag     ┐
//!   u32    p0             │ the EncoderSpec, via
//!   u64    p1             │ EncoderSpec::header_fields — any reader can
//!   u64    p2             │ verify a model trained from this cache used
//!   u64    seed           ┘ the same encoder family
//!   u64    n              total rows (patched on finalize; u64::MAX while
//!                         the writer is still open — readers reject it)
//!   repeated chunk records (identical to v1):
//!     u32    rows in this chunk
//!     u64    payload bytes (= rows labels + rows·stride packed words)
//!     [i8]   labels (one byte per row)
//!     [u64]  packed code words (row-major, PackedCodes layout)
//!     u64    FNV-1a checksum over the rows field + payload bytes
//! ```
//!
//! v1 (legacy — still readable; always b-bit minwise):
//!
//! ```text
//!   magic  b"BBHC"
//!   u32    format version (= 1)
//!   u32    b / u64 k / u64 d / u64 seed   (⇒ EncoderSpec::Bbit)
//!   u64    n
//!   repeated chunk records as above
//! ```
//!
//! Only packed-code schemes are cacheable (the record payload *is* the
//! [`PackedCodes`] word stream); the v2 header's tag space covers the
//! sparse schemes too so the format never needs another bump to learn
//! them.  Records are chunk-granular on purpose: the writer is fed by the
//! pipeline's in-order collector ([`CacheSink`](crate::coordinator::sink)),
//! and the reader replays the identical chunk stream into the streaming
//! trainer, so `hash → cache → train` and `hash → train` see byte-identical
//! data in identical order.

use std::fs::File;
use std::io::{BufReader, BufWriter, Read, Seek, SeekFrom, Write};
use std::path::Path;

use crate::encode::encoder::EncoderSpec;
use crate::encode::expansion::BbitDataset;
use crate::encode::packed::PackedCodes;
use crate::{Error, Result};

/// File magic for the hashed-chunk cache.
pub const CACHE_MAGIC: &[u8; 4] = b"BBHC";
/// Current format version (v2: scheme-tagged spec header).
pub const CACHE_VERSION: u32 = 2;
/// Oldest version the reader still accepts.
pub const CACHE_VERSION_MIN: u32 = 1;
/// v2 header bytes before the first record
/// (magic + version + tag + p0 + p1 + p2 + seed + n).
const HEADER_BYTES_V2: u64 = 4 + 4 + 4 + 4 + 8 + 8 + 8 + 8;
/// Byte offset of the v2 `n` field (patched by `finalize`).
const N_OFFSET_V2: u64 = HEADER_BYTES_V2 - 8;
/// Placeholder `n` while a writer is open; readers reject it.
const N_UNFINALIZED: u64 = u64::MAX;

/// The encoder recipe + row count stored in the cache header.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct CacheMeta {
    /// The encoder the cached codes were produced with.
    pub spec: EncoderSpec,
    /// Total rows across all records.
    pub n: u64,
}

impl CacheMeta {
    /// Encoded dimensionality (2^b·k for packed schemes) a solver trains
    /// against.
    pub fn expanded_dim(&self) -> usize {
        self.spec.output_dim()
    }
}

/// Incremental FNV-1a (64-bit) — per-record integrity, not cryptographic.
struct Fnv1a(u64);

impl Fnv1a {
    fn new() -> Self {
        Fnv1a(0xcbf2_9ce4_8422_2325)
    }

    fn update(&mut self, bytes: &[u8]) {
        for &byte in bytes {
            self.0 ^= byte as u64;
            self.0 = self.0.wrapping_mul(0x0000_0100_0000_01b3);
        }
    }

    fn finish(&self) -> u64 {
        self.0
    }
}

/// The packed-geometry (b, codes-per-row, stride) of a cacheable spec.
fn packed_geometry(spec: &EncoderSpec) -> Result<(u32, usize, usize)> {
    let (b, k) = spec.packed_geometry().ok_or_else(|| {
        Error::InvalidArg(format!(
            "cache stores packed b-bit codes; encoder scheme {:?} emits sparse rows",
            spec.scheme()
        ))
    })?;
    Ok((b, k, (k * b as usize).div_ceil(64)))
}

/// Buffered, append-only cache writer.  Records go out as chunks arrive;
/// [`finalize`](Self::finalize) patches the row count into the header.
pub struct CacheWriter<W: Write + Seek> {
    out: W,
    meta: CacheMeta,
    b: u32,
    k: usize,
    stride: usize,
    finalized: bool,
    /// Reusable record-payload staging buffer (labels + words serialized
    /// once, then checksummed and written as single bulk calls).
    scratch: Vec<u8>,
}

impl CacheWriter<BufWriter<File>> {
    /// Create (truncating) a cache file for the given encoder spec.
    pub fn create<P: AsRef<Path>>(path: P, spec: &EncoderSpec) -> Result<Self> {
        CacheWriter::new(BufWriter::with_capacity(1 << 20, File::create(path)?), spec)
    }
}

impl<W: Write + Seek> CacheWriter<W> {
    pub fn new(mut out: W, spec: &EncoderSpec) -> Result<Self> {
        spec.validate()?;
        let (b, k, stride) = packed_geometry(spec)?;
        let (tag, p0, p1, p2, seed) = spec.header_fields();
        out.write_all(CACHE_MAGIC)?;
        out.write_all(&CACHE_VERSION.to_le_bytes())?;
        out.write_all(&tag.to_le_bytes())?;
        out.write_all(&p0.to_le_bytes())?;
        for v in [p1, p2, seed, N_UNFINALIZED] {
            out.write_all(&v.to_le_bytes())?;
        }
        Ok(CacheWriter {
            out,
            meta: CacheMeta { spec: *spec, n: 0 },
            b,
            k,
            stride,
            finalized: false,
            scratch: Vec::new(),
        })
    }

    /// Rows written so far.
    pub fn rows_written(&self) -> u64 {
        self.meta.n
    }

    /// Append one hashed chunk as a checksummed record.
    pub fn write_chunk(&mut self, codes: &PackedCodes, labels: &[i8]) -> Result<()> {
        if self.finalized {
            return Err(Error::InvalidArg("cache writer already finalized".into()));
        }
        if codes.b != self.b || codes.k != self.k {
            return Err(Error::InvalidArg(format!(
                "chunk geometry (b={}, k={}) does not match cache (b={}, k={})",
                codes.b, codes.k, self.b, self.k
            )));
        }
        if codes.n != labels.len() {
            return Err(Error::InvalidArg(format!(
                "chunk has {} rows but {} labels",
                codes.n,
                labels.len()
            )));
        }
        if codes.n == 0 {
            return Ok(()); // empty chunks carry no information
        }
        let rows = u32::try_from(codes.n)
            .map_err(|_| Error::InvalidArg("chunk larger than u32 rows".into()))?;
        // stage the payload once (labels as two's-complement bytes, then
        // little-endian words) so checksum + IO run over whole slices
        self.scratch.clear();
        self.scratch.reserve(codes.n + 8 * codes.words().len());
        self.scratch.extend(labels.iter().map(|&l| l as u8));
        for &word in codes.words() {
            self.scratch.extend_from_slice(&word.to_le_bytes());
        }
        let payload_len = self.scratch.len() as u64;
        let mut sum = Fnv1a::new();
        sum.update(&rows.to_le_bytes());
        sum.update(&self.scratch);
        self.out.write_all(&rows.to_le_bytes())?;
        self.out.write_all(&payload_len.to_le_bytes())?;
        self.out.write_all(&self.scratch)?;
        self.out.write_all(&sum.finish().to_le_bytes())?;
        self.meta.n += codes.n as u64;
        Ok(())
    }

    /// Patch the header row count and flush.  Idempotent; a cache that was
    /// never finalized (crash mid-write) is rejected by the reader.
    pub fn finalize(&mut self) -> Result<()> {
        if self.finalized {
            return Ok(());
        }
        self.out.seek(SeekFrom::Start(N_OFFSET_V2))?;
        self.out.write_all(&self.meta.n.to_le_bytes())?;
        self.out.seek(SeekFrom::End(0))?;
        self.out.flush()?;
        self.finalized = true;
        Ok(())
    }
}

/// Sequential cache reader: header up front (v1 or v2), then one chunk
/// per [`next_chunk`](Self::next_chunk) call with checksum verification —
/// constant memory regardless of corpus size.
pub struct CacheReader<R: Read> {
    inner: R,
    meta: CacheMeta,
    b: u32,
    k: usize,
    stride: usize,
    rows_read: u64,
    poisoned: bool,
}

impl CacheReader<BufReader<File>> {
    pub fn open<P: AsRef<Path>>(path: P) -> Result<Self> {
        CacheReader::new(BufReader::with_capacity(1 << 20, File::open(path)?))
    }
}

impl<R: Read> CacheReader<R> {
    pub fn new(mut inner: R) -> Result<Self> {
        let mut magic = [0u8; 4];
        inner.read_exact(&mut magic)?;
        if &magic != CACHE_MAGIC {
            return Err(Error::InvalidArg("bad cache magic (not a BBHC file)".into()));
        }
        let mut u32buf = [0u8; 4];
        let mut u64buf = [0u8; 8];
        let mut next_u32 = |r: &mut R| -> Result<u32> {
            r.read_exact(&mut u32buf)?;
            Ok(u32::from_le_bytes(u32buf))
        };
        let mut next_u64 = |r: &mut R| -> Result<u64> {
            r.read_exact(&mut u64buf)?;
            Ok(u64::from_le_bytes(u64buf))
        };
        let version = next_u32(&mut inner)?;
        let (spec, n) = match version {
            // v1: fixed b-bit header {b, k, d, seed}
            1 => {
                let b = next_u32(&mut inner)?;
                let k = next_u64(&mut inner)? as usize;
                let d = next_u64(&mut inner)?;
                let seed = next_u64(&mut inner)?;
                let n = next_u64(&mut inner)?;
                (EncoderSpec::Bbit { b, k, d, seed }, n)
            }
            // v2: scheme-tagged EncoderSpec
            2 => {
                let tag = next_u32(&mut inner)?;
                let p0 = next_u32(&mut inner)?;
                let p1 = next_u64(&mut inner)?;
                let p2 = next_u64(&mut inner)?;
                let seed = next_u64(&mut inner)?;
                let n = next_u64(&mut inner)?;
                (EncoderSpec::from_header_fields(tag, p0, p1, p2, seed)?, n)
            }
            v => {
                return Err(Error::InvalidArg(format!(
                    "unsupported cache version {v} (expected {CACHE_VERSION_MIN}..={CACHE_VERSION})"
                )))
            }
        };
        spec.validate()
            .map_err(|e| Error::InvalidArg(format!("corrupt cache header: {e}")))?;
        if n == N_UNFINALIZED {
            return Err(Error::InvalidArg(
                "cache was never finalized (writer crashed mid-write?)".into(),
            ));
        }
        let (b, k, stride) = packed_geometry(&spec)?;
        Ok(CacheReader {
            inner,
            meta: CacheMeta { spec, n },
            b,
            k,
            stride,
            rows_read: 0,
            poisoned: false,
        })
    }

    /// The encoder recipe + row count from the header.
    pub fn meta(&self) -> CacheMeta {
        self.meta
    }

    /// Read and verify the next chunk record; `None` once all `meta.n`
    /// rows have been replayed.
    pub fn next_chunk(&mut self) -> Result<Option<(PackedCodes, Vec<i8>)>> {
        if self.poisoned {
            return Err(Error::InvalidArg("cache reader poisoned by earlier error".into()));
        }
        if self.rows_read >= self.meta.n {
            return Ok(None);
        }
        match self.read_record() {
            Ok(chunk) => Ok(Some(chunk)),
            Err(e) => {
                self.poisoned = true;
                Err(e)
            }
        }
    }

    fn read_record(&mut self) -> Result<(PackedCodes, Vec<i8>)> {
        let mut u32buf = [0u8; 4];
        let mut u64buf = [0u8; 8];
        self.inner.read_exact(&mut u32buf)?;
        let rows = u32::from_le_bytes(u32buf) as usize;
        self.inner.read_exact(&mut u64buf)?;
        let payload_len = u64::from_le_bytes(u64buf);
        let expect = rows as u64 + 8 * rows as u64 * self.stride as u64;
        if rows == 0 || payload_len != expect {
            return Err(Error::InvalidArg(format!(
                "corrupt cache record at row {}: {} rows, payload {} (expected {})",
                self.rows_read, rows, payload_len, expect
            )));
        }
        if self.rows_read + rows as u64 > self.meta.n {
            return Err(Error::InvalidArg(format!(
                "cache records overrun header count ({} + {} > {})",
                self.rows_read, rows, self.meta.n
            )));
        }
        let mut sum = Fnv1a::new();
        sum.update(&u32buf);
        let mut label_bytes = vec![0u8; rows];
        self.inner.read_exact(&mut label_bytes)?;
        sum.update(&label_bytes);
        let mut word_bytes = vec![0u8; 8 * rows * self.stride];
        self.inner.read_exact(&mut word_bytes)?;
        sum.update(&word_bytes);
        self.inner.read_exact(&mut u64buf)?;
        let stored = u64::from_le_bytes(u64buf);
        if stored != sum.finish() {
            return Err(Error::InvalidArg(format!(
                "cache checksum mismatch at row {} (stored {stored:#018x}, computed {:#018x})",
                self.rows_read,
                sum.finish()
            )));
        }
        let labels: Vec<i8> = label_bytes.into_iter().map(|v| v as i8).collect();
        let words: Vec<u64> = word_bytes
            .chunks_exact(8)
            .map(|c| u64::from_le_bytes(c.try_into().unwrap()))
            .collect();
        let codes = PackedCodes::from_words(self.b, self.k, rows, words)?;
        self.rows_read += rows as u64;
        Ok((codes, labels))
    }

    /// Materialize the whole cache (small inputs / batch solvers; the
    /// streaming trainer never calls this).
    pub fn read_all(mut self) -> Result<BbitDataset> {
        let mut all = PackedCodes::new(self.b, self.k);
        let mut labels = Vec::new();
        while let Some((codes, ls)) = self.next_chunk()? {
            all.extend(&codes)?;
            labels.extend(ls);
        }
        Ok(BbitDataset::new(all, labels))
    }
}

impl<R: Read> Iterator for CacheReader<R> {
    type Item = Result<(PackedCodes, Vec<i8>)>;

    fn next(&mut self) -> Option<Self::Item> {
        self.next_chunk().transpose()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::Rng;
    use std::io::Cursor;

    fn random_chunk(b: u32, k: usize, rows: usize, rng: &mut Rng) -> (PackedCodes, Vec<i8>) {
        let mut pc = PackedCodes::new(b, k);
        let mut labels = Vec::with_capacity(rows);
        for _ in 0..rows {
            let row: Vec<u16> = (0..k).map(|_| rng.below(1 << b) as u16).collect();
            pc.push_row(&row).unwrap();
            labels.push(if rng.bool() { 1 } else { -1 });
        }
        (pc, labels)
    }

    fn bbit_spec(b: u32, k: usize, d: u64, seed: u64) -> EncoderSpec {
        EncoderSpec::Bbit { b, k, d, seed }
    }

    /// Property-style roundtrip over geometries and ragged chunk sizes.
    #[test]
    fn roundtrip_random_geometries() {
        let mut rng = Rng::new(0xCAFE);
        for &(b, k) in &[(1u32, 64usize), (7, 33), (8, 200), (12, 37), (16, 5)] {
            let sizes = [1usize, 17, 256, 3];
            let mut buf = Cursor::new(Vec::new());
            let spec = bbit_spec(b, k, 1 << 30, 42);
            let mut w = CacheWriter::new(&mut buf, &spec).unwrap();
            let mut chunks = Vec::new();
            for &rows in &sizes {
                let (pc, ls) = random_chunk(b, k, rows, &mut rng);
                w.write_chunk(&pc, &ls).unwrap();
                chunks.push((pc, ls));
            }
            w.finalize().unwrap();
            w.finalize().unwrap(); // idempotent
            buf.set_position(0);
            let mut r = CacheReader::new(&mut buf).unwrap();
            let meta = r.meta();
            assert_eq!(meta, CacheMeta { spec, n: sizes.iter().sum::<usize>() as u64 });
            for (pc, ls) in &chunks {
                let (got_pc, got_ls) = r.next_chunk().unwrap().unwrap();
                assert_eq!(&got_pc, pc, "b={b} k={k}");
                assert_eq!(&got_ls, ls);
            }
            assert!(r.next_chunk().unwrap().is_none());
            assert!(r.next_chunk().unwrap().is_none()); // fused
        }
    }

    #[test]
    fn oph_spec_roundtrips_through_header() {
        let mut rng = Rng::new(0x0F4);
        let spec = EncoderSpec::Oph { bins: 24, b: 6, seed: 9 };
        let mut buf = Cursor::new(Vec::new());
        let mut w = CacheWriter::new(&mut buf, &spec).unwrap();
        let (pc, ls) = random_chunk(6, 24, 11, &mut rng);
        w.write_chunk(&pc, &ls).unwrap();
        w.finalize().unwrap();
        buf.set_position(0);
        let mut r = CacheReader::new(&mut buf).unwrap();
        assert_eq!(r.meta().spec, spec);
        assert_eq!(r.meta().n, 11);
        assert_eq!(r.meta().expanded_dim(), (1 << 6) * 24);
        let (got, _) = r.next_chunk().unwrap().unwrap();
        assert_eq!(got, pc);
    }

    #[test]
    fn sparse_specs_are_rejected_by_writer() {
        let buf = Cursor::new(Vec::new());
        assert!(CacheWriter::new(buf, &EncoderSpec::Vw { bins: 64, seed: 1 }).is_err());
        let buf = Cursor::new(Vec::new());
        assert!(CacheWriter::new(buf, &EncoderSpec::Rp { proj: 64, s: 1.0, seed: 1 }).is_err());
    }

    /// Hand-written v1 bytes must keep parsing as EncoderSpec::Bbit.
    #[test]
    fn v1_cache_is_still_readable() {
        let (b, k, d, seed) = (8u32, 16usize, 1u64 << 20, 7u64);
        let mut rng = Rng::new(0x01d);
        let (pc, ls) = random_chunk(b, k, 5, &mut rng);
        let mut bytes = Vec::new();
        bytes.extend_from_slice(CACHE_MAGIC);
        bytes.extend_from_slice(&1u32.to_le_bytes()); // version 1
        bytes.extend_from_slice(&b.to_le_bytes());
        for v in [k as u64, d, seed, 5u64] {
            bytes.extend_from_slice(&v.to_le_bytes());
        }
        // one v1 record (same record format as v2)
        let stride = (k * b as usize).div_ceil(64);
        let rows = 5u32;
        let mut payload = Vec::new();
        payload.extend(ls.iter().map(|&l| l as u8));
        for &word in pc.words() {
            payload.extend_from_slice(&word.to_le_bytes());
        }
        assert_eq!(payload.len(), 5 + 8 * 5 * stride);
        let mut sum = Fnv1a::new();
        sum.update(&rows.to_le_bytes());
        sum.update(&payload);
        bytes.extend_from_slice(&rows.to_le_bytes());
        bytes.extend_from_slice(&(payload.len() as u64).to_le_bytes());
        bytes.extend_from_slice(&payload);
        bytes.extend_from_slice(&sum.finish().to_le_bytes());

        let mut r = CacheReader::new(Cursor::new(bytes)).unwrap();
        assert_eq!(r.meta().spec, EncoderSpec::Bbit { b, k, d, seed });
        assert_eq!(r.meta().n, 5);
        let (got_pc, got_ls) = r.next_chunk().unwrap().unwrap();
        assert_eq!(got_pc, pc);
        assert_eq!(got_ls, ls);
        assert!(r.next_chunk().unwrap().is_none());
    }

    #[test]
    fn unknown_version_is_rejected() {
        let mut bytes = Vec::new();
        bytes.extend_from_slice(CACHE_MAGIC);
        bytes.extend_from_slice(&3u32.to_le_bytes());
        bytes.extend_from_slice(&[0u8; 40]);
        assert!(CacheReader::new(Cursor::new(bytes)).is_err());
    }

    #[test]
    fn empty_cache_roundtrips() {
        let mut buf = Cursor::new(Vec::new());
        let mut w = CacheWriter::new(&mut buf, &bbit_spec(8, 16, 1 << 20, 7)).unwrap();
        let empty = PackedCodes::new(8, 16);
        w.write_chunk(&empty, &[]).unwrap(); // dropped, not an error
        w.finalize().unwrap();
        buf.set_position(0);
        let ds = CacheReader::new(&mut buf).unwrap().read_all().unwrap();
        assert_eq!(ds.len(), 0);
    }

    #[test]
    fn unfinalized_cache_is_rejected() {
        let mut buf = Cursor::new(Vec::new());
        let mut w = CacheWriter::new(&mut buf, &bbit_spec(8, 16, 1 << 20, 7)).unwrap();
        let (pc, ls) = random_chunk(8, 16, 5, &mut Rng::new(1));
        w.write_chunk(&pc, &ls).unwrap();
        // no finalize
        drop(w);
        buf.set_position(0);
        assert!(CacheReader::new(&mut buf).is_err());
    }

    #[test]
    fn corruption_is_detected() {
        let mut rng = Rng::new(9);
        let mut buf = Cursor::new(Vec::new());
        let mut w = CacheWriter::new(&mut buf, &bbit_spec(8, 32, 1 << 20, 3)).unwrap();
        let (pc, ls) = random_chunk(8, 32, 40, &mut rng);
        w.write_chunk(&pc, &ls).unwrap();
        w.finalize().unwrap();
        let mut bytes = buf.into_inner();
        // flip one payload byte past the header
        let target = HEADER_BYTES_V2 as usize + 12 + 7;
        bytes[target] ^= 0x40;
        let mut r = CacheReader::new(Cursor::new(bytes)).unwrap();
        assert!(r.next_chunk().is_err());
        assert!(r.next_chunk().is_err()); // poisoned stays poisoned
    }

    #[test]
    fn truncated_cache_is_detected() {
        let mut buf = Cursor::new(Vec::new());
        let mut w = CacheWriter::new(&mut buf, &bbit_spec(4, 8, 1 << 16, 1)).unwrap();
        let (pc, ls) = random_chunk(4, 8, 10, &mut Rng::new(2));
        w.write_chunk(&pc, &ls).unwrap();
        w.finalize().unwrap();
        let bytes = buf.into_inner();
        let cut = &bytes[..bytes.len() - 9]; // lose the tail of the record
        let mut r = CacheReader::new(Cursor::new(cut.to_vec())).unwrap();
        assert!(r.next_chunk().is_err());
    }

    #[test]
    fn geometry_mismatch_rejected_by_writer() {
        let mut buf = Cursor::new(Vec::new());
        let mut w = CacheWriter::new(&mut buf, &bbit_spec(8, 16, 1 << 20, 7)).unwrap();
        let (pc, ls) = random_chunk(8, 17, 3, &mut Rng::new(3));
        assert!(w.write_chunk(&pc, &ls).is_err());
        let (pc, _) = random_chunk(8, 16, 3, &mut Rng::new(4));
        assert!(w.write_chunk(&pc, &[1, -1]).is_err()); // label count
    }
}
