//! Synthetic rcv1-like / webspam-like corpus generator.
//!
//! Substitution for the paper's proprietary-scale datasets (DESIGN.md §5):
//! we cannot ship rcv1 or webspam, so we generate a corpus with the three
//! properties every claim in the paper actually depends on:
//!
//! 1. **binary, sparse, high-dimensional** data (sets of token/feature ids
//!    with Zipfian frequencies, like parsed n-gram text);
//! 2. **label signal carried by set resemblance**: same-class documents
//!    draw from the same class-conditional token distribution, so their
//!    pairwise resemblance is higher — which is exactly the signal minwise
//!    hashing preserves and random-sign hashing damages;
//! 3. **r = f/D → 0** after feature expansion (so the Eq. 5 sparse limit
//!    applies, as in the paper).
//!
//! Each document is a set of base tokens; `expand.rs` then applies the
//! paper's own construction (unigrams + pairwise + 1/30 of 3-way) to blow
//! the dimensionality up.

use crate::data::dataset::{Example, SparseDataset};
use crate::util::rng::Zipf;
use crate::util::Rng;

/// Corpus generator configuration.
#[derive(Clone, Debug)]
pub struct CorpusConfig {
    /// Number of documents.
    pub n_docs: usize,
    /// Base vocabulary size (rcv1's original feature count scaled down).
    pub vocab: u32,
    /// Zipf exponent of token frequencies.
    pub zipf_alpha: f64,
    /// Mean document length in tokens (Poisson).
    pub mean_tokens: f64,
    /// Fraction of tokens drawn from the class-conditional distribution
    /// (the rest come from a shared background — controls class
    /// separability and within-class resemblance).
    pub class_signal: f64,
    /// Fraction of positive-class documents.
    pub pos_fraction: f64,
    /// Generator seed.
    pub seed: u64,
}

impl CorpusConfig {
    /// rcv1-like preset (before expansion): moderately long docs over a
    /// 12k vocabulary; expansion takes D to 2^30 (see expand.rs).
    pub fn rcv1_like(n_docs: usize, seed: u64) -> Self {
        CorpusConfig {
            n_docs,
            vocab: 12_000,
            zipf_alpha: 1.05,
            mean_tokens: 40.0,
            class_signal: 0.55,
            pos_fraction: 0.47, // rcv1 CCAT-ish balance
            seed,
        }
    }

    /// webspam-like preset: no expansion, denser documents, used for the
    /// Figure 8 permutation-vs-universal comparison (needs a feasible D).
    pub fn webspam_like(n_docs: usize, seed: u64) -> Self {
        CorpusConfig {
            n_docs,
            vocab: 1 << 20,
            zipf_alpha: 1.02,
            mean_tokens: 350.0,
            class_signal: 0.5,
            pos_fraction: 0.61, // webspam's 61% positive
            seed,
        }
    }
}

/// Class-conditional token model: the positive class samples token ranks
/// through a per-class rank rotation of the shared Zipf, so both classes
/// see the same marginal frequency law but different token identities.
pub struct CorpusGenerator {
    cfg: CorpusConfig,
    zipf: Zipf,
    /// Per-class rank rotation offsets (class 0 = negative, 1 = positive).
    rot: [u32; 2],
}

impl CorpusGenerator {
    pub fn new(cfg: CorpusConfig) -> Self {
        assert!(cfg.vocab >= 16 && cfg.n_docs > 0);
        let zipf = Zipf::new(cfg.vocab as u64, cfg.zipf_alpha);
        // rotate class-1 ranks by a third of the vocabulary
        let rot = [0, cfg.vocab / 3];
        CorpusGenerator { cfg, zipf, rot }
    }

    /// Map a sampled rank to a token id for `class`, rotating the rank
    /// order so classes prefer different tokens.
    #[inline]
    fn class_token(&self, rank: u64, class: usize) -> u32 {
        ((rank as u32).wrapping_add(self.rot[class])) % self.cfg.vocab
    }

    /// Generate one document: (label, sorted unique token set).
    pub fn gen_doc(&self, rng: &mut Rng) -> Example {
        let positive = rng.f64() < self.cfg.pos_fraction;
        let class = positive as usize;
        let len = rng.poisson(self.cfg.mean_tokens).max(3) as usize;
        let mut tokens = Vec::with_capacity(len);
        for _ in 0..len {
            let rank = self.zipf.sample(rng);
            let tok = if rng.f64() < self.cfg.class_signal {
                self.class_token(rank, class)
            } else {
                // shared background: un-rotated rank order
                rank as u32
            };
            tokens.push(tok);
        }
        Example::binary(if positive { 1 } else { -1 }, tokens)
    }

    /// Generate the full corpus as a dataset over the base vocabulary.
    pub fn generate(&self) -> SparseDataset {
        let mut rng = Rng::new(self.cfg.seed);
        let mut ds = SparseDataset::new(self.cfg.vocab as u64);
        for _ in 0..self.cfg.n_docs {
            ds.push(&self.gen_doc(&mut rng));
        }
        ds
    }

    pub fn config(&self) -> &CorpusConfig {
        &self.cfg
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::hashing::minwise::resemblance;

    #[test]
    fn corpus_is_reproducible() {
        let cfg = CorpusConfig::rcv1_like(50, 7);
        let a = CorpusGenerator::new(cfg.clone()).generate();
        let b = CorpusGenerator::new(cfg).generate();
        assert_eq!(a.indices, b.indices);
        assert_eq!(a.labels, b.labels);
    }

    #[test]
    fn documents_look_like_text() {
        let ds = CorpusGenerator::new(CorpusConfig::rcv1_like(200, 11)).generate();
        let s = ds.stats();
        assert_eq!(s.n, 200);
        // Poisson(40) minus dedup: tokens repeat under Zipf, so expect
        // roughly 20–40 distinct tokens per doc.
        assert!(s.nnz_mean > 10.0 && s.nnz_mean < 45.0, "{}", s.nnz_mean);
        assert!(s.pos_fraction > 0.3 && s.pos_fraction < 0.65);
        ds.validate().unwrap();
    }

    #[test]
    fn same_class_docs_are_more_similar() {
        // The property the whole reproduction rests on: within-class
        // resemblance must exceed across-class resemblance.
        let ds = CorpusGenerator::new(CorpusConfig::rcv1_like(300, 13)).generate();
        let (mut within, mut across) = (Vec::new(), Vec::new());
        for i in 0..100 {
            for j in (i + 1)..100 {
                let r = resemblance(ds.row(i).0, ds.row(j).0);
                if ds.labels[i] == ds.labels[j] {
                    within.push(r);
                } else {
                    across.push(r);
                }
            }
        }
        let w = crate::util::stats::mean(&within);
        let a = crate::util::stats::mean(&across);
        assert!(w > 1.3 * a, "within {w} across {a}");
    }

    #[test]
    fn webspam_preset_is_denser() {
        let ds = CorpusGenerator::new(CorpusConfig::webspam_like(50, 17)).generate();
        assert!(ds.stats().nnz_mean > 100.0);
        assert_eq!(ds.dim, 1 << 20);
    }
}
