//! Streaming LibSVM-format reader/writer.
//!
//! All the paper's datasets are "in LibSVM format", and its Table 2
//! measures *data-loading time* as the baseline every preprocessing cost is
//! compared against — so parsing speed matters and reading is fully
//! streaming (constant memory, chunked), never whole-file.
//!
//! Format per line: `<label> <idx>:<val> <idx>:<val> ...` with 1-based or
//! 0-based indices (we accept both, preserving the raw index), `+1/-1/0/1`
//! labels, `#` comments, and blank lines skipped.
//!
//! Two parsers share those semantics:
//!
//! - [`LibsvmReader`] — the legacy line reader (`BufReader::lines()`): one
//!   `String` plus two `Vec`s per document, UTF-8 validated.  Kept for one
//!   release behind the CLI's `--legacy-reader` flag and as the
//!   conformance reference.
//! - the **byte-block fast path** — [`BlockReader`] carves the input into
//!   newline-aligned byte slabs ([`RawBlock`], recycled buffers), and
//!   [`parse_block`] scans them as raw `&[u8]`: no per-line `String`, no
//!   UTF-8 validation, hand-rolled integer/float token parsing, rows
//!   landing in a caller-owned [`ParsedChunk`] (CSR scratch) so steady-
//!   state parsing allocates nothing per document.  This is what lets the
//!   pipeline parse *in the workers* and track the paper's "preprocessing
//!   ≈ loading" bound.  Whitespace handling is ASCII (the format is ASCII);
//!   the readers agree byte-for-byte on every ASCII input.

use std::fs::File;
use std::io::{BufRead, BufReader, BufWriter, Read, Write};
use std::path::Path;
use std::sync::mpsc::Receiver;

use crate::data::dataset::{Example, SparseDataset};
use crate::{Error, Result};

/// Streaming reader yielding one [`Example`] per data line.
pub struct LibsvmReader<R: Read> {
    lines: std::io::Lines<BufReader<R>>,
    line_no: usize,
    /// Treat all values as 1.0 and store a binary example (the paper's
    /// datasets are binary; skipping float parsing doubles throughput).
    pub binary: bool,
}

impl LibsvmReader<File> {
    pub fn open<P: AsRef<Path>>(path: P) -> Result<Self> {
        Ok(LibsvmReader::new(File::open(path)?))
    }
}

impl<R: Read> LibsvmReader<R> {
    pub fn new(inner: R) -> Self {
        LibsvmReader {
            lines: BufReader::with_capacity(1 << 20, inner).lines(),
            line_no: 0,
            binary: false,
        }
    }

    pub fn binary(mut self) -> Self {
        self.binary = true;
        self
    }

    fn parse_line(&self, line: &str) -> Result<Option<Example>> {
        let line = line.trim();
        if line.is_empty() || line.starts_with('#') {
            return Ok(None);
        }
        let mut parts = line.split_ascii_whitespace();
        let label_tok = parts.next().ok_or_else(|| Error::LibsvmParse {
            line: self.line_no,
            msg: "missing label".into(),
        })?;
        let label: i8 = match label_tok {
            "+1" | "1" => 1,
            "-1" => -1,
            "0" => -1, // some dumps use 0/1
            other => other.parse::<f32>().map(|v| if v > 0.0 { 1 } else { -1 }).map_err(
                |_| Error::LibsvmParse {
                    line: self.line_no,
                    msg: format!("bad label {other:?}"),
                },
            )?,
        };
        let mut indices = Vec::new();
        let mut values: Vec<f32> = Vec::new();
        let mut all_ones = true;
        for tok in parts {
            if tok.starts_with('#') {
                break;
            }
            let (i_str, v_str) = tok.split_once(':').ok_or_else(|| Error::LibsvmParse {
                line: self.line_no,
                msg: format!("bad feature token {tok:?}"),
            })?;
            let idx: u32 = i_str.parse().map_err(|_| Error::LibsvmParse {
                line: self.line_no,
                msg: format!("bad index {i_str:?}"),
            })?;
            indices.push(idx);
            if !self.binary {
                let v: f32 = v_str.parse().map_err(|_| Error::LibsvmParse {
                    line: self.line_no,
                    msg: format!("bad value {v_str:?}"),
                })?;
                if v != 1.0 {
                    all_ones = false;
                }
                values.push(v);
            }
        }
        // normalize: sorted unique indices (values follow their index)
        if !indices.windows(2).all(|w| w[0] < w[1]) {
            if self.binary || all_ones {
                indices.sort_unstable();
                indices.dedup();
            } else {
                let mut pairs: Vec<(u32, f32)> =
                    indices.iter().copied().zip(values.iter().copied()).collect();
                pairs.sort_unstable_by_key(|p| p.0);
                pairs.dedup_by_key(|p| p.0);
                indices = pairs.iter().map(|p| p.0).collect();
                values = pairs.iter().map(|p| p.1).collect();
            }
        }
        let values = if self.binary || all_ones { None } else { Some(values) };
        Ok(Some(Example { label, indices, values }))
    }
}

impl<R: Read> Iterator for LibsvmReader<R> {
    type Item = Result<Example>;

    fn next(&mut self) -> Option<Self::Item> {
        loop {
            self.line_no += 1;
            match self.lines.next()? {
                Err(e) => return Some(Err(e.into())),
                Ok(line) => match self.parse_line(&line) {
                    Err(e) => return Some(Err(e)),
                    Ok(Some(ex)) => return Some(Ok(ex)),
                    Ok(None) => continue, // comment/blank
                },
            }
        }
    }
}

/// Chunked streaming: yields `Vec<Example>` of at most `chunk_size` — the
/// unit of work the preprocessing pipeline shards across workers.
pub struct ChunkedReader<R: Read> {
    reader: LibsvmReader<R>,
    chunk_size: usize,
}

impl<R: Read> ChunkedReader<R> {
    pub fn new(reader: LibsvmReader<R>, chunk_size: usize) -> Self {
        assert!(chunk_size > 0);
        ChunkedReader { reader, chunk_size }
    }
}

impl<R: Read> Iterator for ChunkedReader<R> {
    type Item = Result<Vec<Example>>;

    fn next(&mut self) -> Option<Self::Item> {
        let mut chunk = Vec::with_capacity(self.chunk_size);
        for ex in self.reader.by_ref() {
            match ex {
                Ok(e) => {
                    chunk.push(e);
                    if chunk.len() == self.chunk_size {
                        return Some(Ok(chunk));
                    }
                }
                Err(e) => return Some(Err(e)),
            }
        }
        if chunk.is_empty() {
            None
        } else {
            Some(Ok(chunk))
        }
    }
}

// ---------------------------------------------------------------------------
// Byte-block fast path
// ---------------------------------------------------------------------------

/// Default slab size for [`BlockReader`]: big enough that per-block channel
/// and scheduling overhead vanishes (a few thousand documents per block),
/// small enough that `workers + queue` blocks in flight stay cache-friendly.
pub const DEFAULT_BLOCK_BYTES: usize = 256 << 10;

/// One newline-aligned slab of raw LibSVM bytes.
///
/// `bytes` holds only complete lines (the final block of a file may lack
/// its trailing newline); `first_line` is the 1-based file line number of
/// the first line, so workers parsing blocks out of band still report
/// exact error locations.  `end_offset`/`next_line` are the input cursor
/// *after* this block — what `preprocess --resume` journals so a restarted
/// run can re-carve the identical block stream from mid-file.
#[derive(Debug)]
pub struct RawBlock {
    pub bytes: Vec<u8>,
    pub first_line: usize,
    /// Input byte offset one past this block's last byte.
    pub end_offset: u64,
    /// 1-based line number of the first line after this block.
    pub next_line: usize,
}

/// Carves a byte stream into newline-aligned [`RawBlock`]s — the reader
/// stage of the block-parallel ingest path.  The reader does no parsing at
/// all (that moved into the pipeline workers); its per-byte work is one
/// `read` plus a newline count, so a single reader thread feeds many parse
/// workers.  With [`set_recycle`](Self::set_recycle) wired, block buffers
/// returned by the workers are reused, making steady-state reading
/// allocation-free.
pub struct BlockReader<R: Read> {
    inner: R,
    block_bytes: usize,
    /// Bytes after the last newline of the previous read (a partial line).
    carry: Vec<u8>,
    /// 1-based line number of the first line of the next block.
    next_line: usize,
    /// Input byte offset of the first byte of the next block (cumulative
    /// bytes emitted; starts at the resume offset for mid-file readers).
    offset: u64,
    eof: bool,
    done: bool,
    recycle: Option<Receiver<Vec<u8>>>,
}

impl BlockReader<File> {
    pub fn open<P: AsRef<Path>>(path: P) -> Result<Self> {
        Ok(BlockReader::new(File::open(path)?))
    }

    /// Open mid-file for `preprocess --resume`: carving starts at byte
    /// `offset` (which must sit on a line boundary — the resume journal
    /// only records block edges, and blocks end at newlines), with line
    /// numbering continuing from `first_line`.  Because blocks are carved
    /// greedily and contiguously, the stream from here is identical to the
    /// tail of a full-file read that crossed `offset` at a block edge.
    pub fn open_at<P: AsRef<Path>>(path: P, offset: u64, first_line: usize) -> Result<Self> {
        use std::io::Seek;
        let mut f = File::open(path)?;
        f.seek(std::io::SeekFrom::Start(offset))?;
        let mut r = BlockReader::new(f);
        r.offset = offset;
        r.next_line = first_line.max(1);
        Ok(r)
    }
}

impl<R: Read> BlockReader<R> {
    pub fn new(inner: R) -> Self {
        BlockReader {
            inner,
            block_bytes: DEFAULT_BLOCK_BYTES,
            carry: Vec::new(),
            next_line: 1,
            offset: 0,
            eof: false,
            done: false,
            recycle: None,
        }
    }

    pub fn with_block_bytes(mut self, block_bytes: usize) -> Self {
        assert!(block_bytes > 0);
        self.block_bytes = block_bytes;
        self
    }

    /// Attach a recycled-buffer source: `next` drains it (non-blocking)
    /// before allocating a fresh block buffer.  The pipeline's parse
    /// workers send each block's buffer back here once parsed, so the
    /// buffers circulate — the admission-credit loop bounds how many exist.
    pub fn set_recycle(&mut self, rx: Receiver<Vec<u8>>) {
        self.recycle = Some(rx);
    }

    /// Top `buf` up to a newline-aligned slab of at least `block_bytes`
    /// (or to EOF), stashing the trailing partial line in `carry`.
    fn fill(&mut self, buf: &mut Vec<u8>) -> std::io::Result<()> {
        // bytes below this offset are known newline-free (the carry prefix
        // handed in by `next`, plus regions already searched below), so
        // each byte is scanned at most once even when one line spans many
        // growth steps
        let mut scanned = buf.len();
        loop {
            while !self.eof && buf.len() < self.block_bytes {
                let start = buf.len();
                buf.resize(self.block_bytes, 0);
                let n = read_retry(&mut self.inner, &mut buf[start..])?;
                buf.truncate(start + n);
                if n == 0 {
                    self.eof = true;
                }
            }
            if self.eof {
                // final block keeps the unterminated tail line
                return Ok(());
            }
            match buf[scanned..].iter().rposition(|&b| b == b'\n') {
                Some(rel) => {
                    let pos = scanned + rel;
                    self.carry.extend_from_slice(&buf[pos + 1..]);
                    buf.truncate(pos + 1);
                    return Ok(());
                }
                None => {
                    // one line longer than the block: grow until its
                    // newline (or EOF) arrives
                    scanned = buf.len();
                    let start = buf.len();
                    buf.resize(start + self.block_bytes, 0);
                    let n = read_retry(&mut self.inner, &mut buf[start..])?;
                    buf.truncate(start + n);
                    if n == 0 {
                        self.eof = true;
                    }
                }
            }
        }
    }
}

fn read_retry<R: Read>(r: &mut R, buf: &mut [u8]) -> std::io::Result<usize> {
    loop {
        match r.read(buf) {
            Err(e) if e.kind() == std::io::ErrorKind::Interrupted => continue,
            other => return other,
        }
    }
}

impl<R: Read> Iterator for BlockReader<R> {
    type Item = Result<RawBlock>;

    fn next(&mut self) -> Option<Self::Item> {
        if self.done {
            return None;
        }
        let mut buf = self
            .recycle
            .as_ref()
            .and_then(|rx| rx.try_recv().ok())
            .unwrap_or_default();
        buf.clear();
        buf.extend_from_slice(&self.carry);
        self.carry.clear();
        if let Err(e) = self.fill(&mut buf) {
            self.done = true;
            return Some(Err(e.into()));
        }
        if buf.is_empty() {
            self.done = true;
            return None;
        }
        let first_line = self.next_line;
        self.next_line += buf.iter().filter(|&&b| b == b'\n').count();
        self.offset += buf.len() as u64;
        Some(Ok(RawBlock {
            bytes: buf,
            first_line,
            end_offset: self.offset,
            next_line: self.next_line,
        }))
    }
}

/// Reusable CSR-shaped parse target for the byte-block fast path: one
/// growable arena per field instead of two `Vec`s per document, cleared
/// (not freed) between blocks.  After warm-up, parsing through one
/// `ParsedChunk` performs **zero** per-document heap allocations.
#[derive(Clone, Debug, Default)]
pub struct ParsedChunk {
    labels: Vec<i8>,
    indptr: Vec<usize>,
    indices: Vec<u32>,
    /// Parallel to `indices` when parsed with `binary = false`; empty in
    /// binary mode (values are never even scanned, like the legacy
    /// reader's `binary` flag).
    values: Vec<f32>,
    /// Per row: does the row carry real values (`Example::values = Some`)?
    /// False for binary mode and for all-ones rows, mirroring the legacy
    /// reader's per-row `None` promotion.
    valued: Vec<bool>,
    /// Sort/dedup scratch for valued rows with out-of-order indices.
    pairs: Vec<(u32, f32)>,
}

impl ParsedChunk {
    pub fn len(&self) -> usize {
        self.labels.len()
    }

    pub fn is_empty(&self) -> bool {
        self.labels.is_empty()
    }

    /// Drop all rows, keeping every buffer's capacity.
    pub fn clear(&mut self) {
        self.labels.clear();
        self.indptr.clear();
        self.indptr.push(0);
        self.indices.clear();
        self.values.clear();
        self.valued.clear();
    }

    pub fn labels(&self) -> &[i8] {
        &self.labels
    }

    pub fn label(&self, i: usize) -> i8 {
        self.labels[i]
    }

    /// Row accessor: (sorted unique indices, values) — `None` values for
    /// binary/all-ones rows, exactly like [`Example::values`].
    pub fn row(&self, i: usize) -> (&[u32], Option<&[f32]>) {
        let (lo, hi) = (self.indptr[i], self.indptr[i + 1]);
        let vals = if self.valued[i] { Some(&self.values[lo..hi]) } else { None };
        (&self.indices[lo..hi], vals)
    }

    /// Iterate rows as `(label, indices, values)`.
    pub fn rows(&self) -> impl Iterator<Item = (i8, &[u32], Option<&[f32]>)> + '_ {
        (0..self.len()).map(move |i| {
            let (idx, vals) = self.row(i);
            (self.labels[i], idx, vals)
        })
    }

    /// Materialize owned [`Example`]s (conformance tests and the trait
    /// default; the hot paths iterate [`rows`](Self::rows) instead).
    pub fn to_examples(&self) -> Vec<Example> {
        self.rows()
            .map(|(label, idx, vals)| Example {
                label,
                indices: idx.to_vec(),
                values: vals.map(|v| v.to_vec()),
            })
            .collect()
    }
}

/// Parse one newline-aligned block of raw LibSVM bytes, appending rows to
/// `out` (callers `clear` between blocks).  `first_line` is the 1-based
/// file line number of the block's first line; `binary` skips value
/// parsing like [`LibsvmReader::binary`].  Semantics — labels, comments,
/// blank lines, index normalization, per-row value promotion, error line
/// numbers — match the legacy line reader example-for-example (the
/// `ingest_fastpath` conformance suite pins this).
pub fn parse_block(
    block: &[u8],
    first_line: usize,
    binary: bool,
    out: &mut ParsedChunk,
) -> Result<()> {
    if out.indptr.is_empty() {
        out.indptr.push(0);
    }
    debug_assert!(
        if binary { out.values.is_empty() } else { out.values.len() == out.indices.len() },
        "one ParsedChunk cannot mix binary and valued parsing"
    );
    for (off, line) in block.split(|&b| b == b'\n').enumerate() {
        parse_line_into(line, first_line + off, binary, out)?;
    }
    Ok(())
}

/// A malformed input line captured by the skip-on-error ingest policy
/// (`--on-error skip`): the 1-based file line number, the raw bytes as
/// they appeared in the input, and what was wrong with them.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct BadLine {
    pub line: usize,
    pub bytes: Vec<u8>,
    pub msg: String,
}

/// [`parse_block`] with the skip-on-error policy: a malformed line is
/// rolled back out of `out` (good rows before and after it are kept),
/// captured into `bad`, and parsing continues.  The default pipeline stays
/// fail-fast via [`parse_block`]; this variant backs `--on-error skip`.
pub fn parse_block_lossy(
    block: &[u8],
    first_line: usize,
    binary: bool,
    out: &mut ParsedChunk,
    bad: &mut Vec<BadLine>,
) {
    if out.indptr.is_empty() {
        out.indptr.push(0);
    }
    for (off, line) in block.split(|&b| b == b'\n').enumerate() {
        if let Err(e) = parse_line_into(line, first_line + off, binary, out) {
            let msg = match e {
                Error::LibsvmParse { msg, .. } => msg,
                other => other.to_string(),
            };
            bad.push(BadLine { line: first_line + off, bytes: line.to_vec(), msg });
        }
    }
}

/// Byte-level scan of one line into `out` (comments/blanks append nothing).
fn parse_line_into(
    line: &[u8],
    line_no: usize,
    binary: bool,
    out: &mut ParsedChunk,
) -> Result<()> {
    let line = trim_ascii(line);
    if line.is_empty() || line[0] == b'#' {
        return Ok(());
    }
    let err = |msg: String| Error::LibsvmParse { line: line_no, msg };
    let mut toks = AsciiTokens { rest: line };
    let label_tok = toks.next().expect("non-empty trimmed line has a token");
    let label: i8 = match label_tok {
        b"+1" | b"1" => 1,
        b"-1" | b"0" => -1, // some dumps use 0/1
        other => match parse_f32_bytes(other) {
            Some(v) if v > 0.0 => 1,
            Some(_) => -1,
            None => {
                return Err(err(format!("bad label {:?}", String::from_utf8_lossy(other))))
            }
        },
    };
    let start = out.indices.len();
    let mut all_ones = true;
    let mut sorted = true;
    for tok in toks {
        if tok[0] == b'#' {
            break;
        }
        let Some(colon) = tok.iter().position(|&b| b == b':') else {
            truncate_row(out, start);
            return Err(err(format!(
                "bad feature token {:?}",
                String::from_utf8_lossy(tok)
            )));
        };
        let Some(idx) = parse_u32_bytes(&tok[..colon]) else {
            truncate_row(out, start);
            return Err(err(format!(
                "bad index {:?}",
                String::from_utf8_lossy(&tok[..colon])
            )));
        };
        if out.indices.len() > start && out.indices[out.indices.len() - 1] >= idx {
            sorted = false;
        }
        out.indices.push(idx);
        if !binary {
            let Some(v) = parse_f32_bytes(&tok[colon + 1..]) else {
                truncate_row(out, start);
                return Err(err(format!(
                    "bad value {:?}",
                    String::from_utf8_lossy(&tok[colon + 1..])
                )));
            };
            if v != 1.0 {
                all_ones = false;
            }
            out.values.push(v);
        }
    }
    // normalize: sorted unique indices (values follow their index) — the
    // same branches, sort and dedup calls as the legacy reader, so rows
    // with duplicate valued indices keep the identical survivor
    if !sorted {
        if binary || all_ones {
            out.indices[start..].sort_unstable();
            // in-place dedup of the sorted row tail (two-pointer)
            let mut w = start + 1;
            let mut r = start + 1;
            while r < out.indices.len() {
                if out.indices[r] != out.indices[w - 1] {
                    out.indices[w] = out.indices[r];
                    w += 1;
                }
                r += 1;
            }
            out.indices.truncate(w);
            if !binary {
                out.values.truncate(out.indices.len()); // all 1.0
            }
        } else {
            out.pairs.clear();
            out.pairs.extend(
                out.indices[start..]
                    .iter()
                    .copied()
                    .zip(out.values[start..].iter().copied()),
            );
            out.pairs.sort_unstable_by_key(|p| p.0);
            out.pairs.dedup_by_key(|p| p.0);
            out.indices.truncate(start);
            out.values.truncate(start);
            out.indices.extend(out.pairs.iter().map(|p| p.0));
            out.values.extend(out.pairs.iter().map(|p| p.1));
        }
    }
    out.labels.push(label);
    out.valued.push(!binary && !all_ones);
    out.indptr.push(out.indices.len());
    Ok(())
}

/// Roll a half-parsed row back out of the arenas (error paths).
fn truncate_row(out: &mut ParsedChunk, start: usize) {
    out.indices.truncate(start);
    out.values.truncate(start); // no-op in binary mode (values stays empty)
}

/// Does `str::trim` strip this ASCII byte?  Every `is_ascii_whitespace`
/// byte plus vertical tab (0x0B), which is Unicode whitespace (so the
/// legacy reader's `trim` eats it at line edges) but not "ascii
/// whitespace" in the std sense.  Tokenization below deliberately sticks
/// to `is_ascii_whitespace`, mirroring `split_ascii_whitespace` — VT
/// separates nothing in either reader.
#[inline]
fn is_trimmed_byte(b: u8) -> bool {
    b.is_ascii_whitespace() || b == 0x0B
}

fn trim_ascii(mut s: &[u8]) -> &[u8] {
    while let [first, rest @ ..] = s {
        if is_trimmed_byte(*first) {
            s = rest;
        } else {
            break;
        }
    }
    while let [rest @ .., last] = s {
        if is_trimmed_byte(*last) {
            s = rest;
        } else {
            break;
        }
    }
    s
}

/// `split_ascii_whitespace` over bytes, zero-copy.
struct AsciiTokens<'a> {
    rest: &'a [u8],
}

impl<'a> Iterator for AsciiTokens<'a> {
    type Item = &'a [u8];

    fn next(&mut self) -> Option<&'a [u8]> {
        let mut i = 0;
        while i < self.rest.len() && self.rest[i].is_ascii_whitespace() {
            i += 1;
        }
        if i == self.rest.len() {
            self.rest = &[];
            return None;
        }
        let s = i;
        while i < self.rest.len() && !self.rest[i].is_ascii_whitespace() {
            i += 1;
        }
        let tok = &self.rest[s..i];
        self.rest = &self.rest[i..];
        Some(tok)
    }
}

/// Hand-rolled `u32` parse: optional `+`, digits, overflow-checked —
/// accepts exactly what `str::parse::<u32>` accepts.
fn parse_u32_bytes(tok: &[u8]) -> Option<u32> {
    let t = tok.strip_prefix(b"+").unwrap_or(tok);
    if t.is_empty() {
        return None;
    }
    let mut v: u64 = 0;
    for &c in t {
        if !c.is_ascii_digit() {
            return None;
        }
        v = v * 10 + (c - b'0') as u64;
        if v > u32::MAX as u64 {
            return None;
        }
    }
    Some(v as u32)
}

const POW10_F32: [f32; 11] = [1e0, 1e1, 1e2, 1e3, 1e4, 1e5, 1e6, 1e7, 1e8, 1e9, 1e10];

/// Hand-rolled decimal `f32` parse, bit-identical to `str::parse::<f32>`.
///
/// Fast path (the Clinger window): mantissa ≤ 2^24 and |exp10| ≤ 10 make
/// both operands of `m · 10^e` exact in f32, so the single multiply/divide
/// is correctly rounded — the same answer the std parser's full algorithm
/// produces.  Everything outside the window (long mantissas, extreme
/// exponents, `inf`/`nan` spellings) falls back to the std parser on the
/// token slice, so acceptance is exactly the legacy reader's.
fn parse_f32_bytes(tok: &[u8]) -> Option<f32> {
    let fallback = |t: &[u8]| std::str::from_utf8(t).ok()?.parse::<f32>().ok();
    let mut i = 0usize;
    let neg = match tok.first()? {
        b'-' => {
            i = 1;
            true
        }
        b'+' => {
            i = 1;
            false
        }
        _ => false,
    };
    let mut mant: u64 = 0;
    let mut digits = 0u32;
    let mut exp10: i32 = 0;
    while i < tok.len() && tok[i].is_ascii_digit() {
        mant = mant * 10 + (tok[i] - b'0') as u64;
        digits += 1;
        i += 1;
        if digits > 17 {
            return fallback(tok);
        }
    }
    let mut any = digits > 0;
    if i < tok.len() && tok[i] == b'.' {
        i += 1;
        while i < tok.len() && tok[i].is_ascii_digit() {
            mant = mant * 10 + (tok[i] - b'0') as u64;
            digits += 1;
            exp10 -= 1;
            i += 1;
            any = true;
            if digits > 17 {
                return fallback(tok);
            }
        }
    }
    if !any {
        return fallback(tok); // "inf", "nan", "", "." — std decides
    }
    if i < tok.len() && (tok[i] == b'e' || tok[i] == b'E') {
        i += 1;
        let eneg = match tok.get(i)? {
            b'-' => {
                i += 1;
                true
            }
            b'+' => {
                i += 1;
                false
            }
            _ => false,
        };
        let mut e: i32 = 0;
        let mut ed = 0u32;
        while i < tok.len() && tok[i].is_ascii_digit() {
            e = e * 10 + (tok[i] - b'0') as i32;
            ed += 1;
            i += 1;
            if ed > 4 {
                return fallback(tok);
            }
        }
        if ed == 0 {
            return fallback(tok); // "1e", "1e+" — std rejects
        }
        exp10 += if eneg { -e } else { e };
    }
    if i != tok.len() {
        return fallback(tok); // trailing junk — std rejects
    }
    if mant <= (1 << 24) && (-10..=10).contains(&exp10) {
        let v = mant as f32;
        let v = if exp10 < 0 {
            v / POW10_F32[(-exp10) as usize]
        } else {
            v * POW10_F32[exp10 as usize]
        };
        return Some(if neg { -v } else { v });
    }
    fallback(tok)
}

/// Load a whole file into a [`SparseDataset`] via the byte-block parser
/// (tests / small inputs only; the pipeline path stays streaming).
pub fn load<P: AsRef<Path>>(path: P, dim: u64) -> Result<SparseDataset> {
    load_with_block_bytes(path, dim, DEFAULT_BLOCK_BYTES)
}

/// [`load`] with an explicit slab size (the CLI's `--block-kb`).
pub fn load_with_block_bytes<P: AsRef<Path>>(
    path: P,
    dim: u64,
    block_bytes: usize,
) -> Result<SparseDataset> {
    let mut ds = SparseDataset::new(dim);
    let mut parsed = ParsedChunk::default();
    for block in BlockReader::open(path)?.with_block_bytes(block_bytes) {
        let block = block?;
        parsed.clear();
        parse_block(&block.bytes, block.first_line, false, &mut parsed)?;
        for (label, idx, vals) in parsed.rows() {
            ds.push_row(label, idx, vals);
        }
    }
    ds.validate()?;
    Ok(ds)
}

/// Streaming writer.
pub struct LibsvmWriter<W: Write> {
    out: BufWriter<W>,
}

impl LibsvmWriter<File> {
    pub fn create<P: AsRef<Path>>(path: P) -> Result<Self> {
        Ok(LibsvmWriter::new(File::create(path)?))
    }
}

impl<W: Write> LibsvmWriter<W> {
    pub fn new(inner: W) -> Self {
        LibsvmWriter { out: BufWriter::with_capacity(1 << 20, inner) }
    }

    pub fn write_example(&mut self, ex: &Example) -> Result<()> {
        let mut line = String::with_capacity(ex.indices.len() * 12 + 4);
        line.push_str(if ex.label > 0 { "+1" } else { "-1" });
        match &ex.values {
            None => {
                for &i in &ex.indices {
                    line.push(' ');
                    push_u32(&mut line, i);
                    line.push_str(":1");
                }
            }
            Some(vals) => {
                for (&i, &v) in ex.indices.iter().zip(vals) {
                    line.push(' ');
                    push_u32(&mut line, i);
                    line.push(':');
                    line.push_str(&format_value(v));
                }
            }
        }
        line.push('\n');
        self.out.write_all(line.as_bytes())?;
        Ok(())
    }

    pub fn write_dataset(&mut self, ds: &SparseDataset) -> Result<()> {
        for ex in ds.iter() {
            self.write_example(&ex)?;
        }
        Ok(())
    }

    pub fn finish(mut self) -> Result<()> {
        self.out.flush()?;
        Ok(())
    }
}

fn push_u32(s: &mut String, v: u32) {
    let mut buf = [0u8; 10];
    let mut i = buf.len();
    let mut v = v;
    loop {
        i -= 1;
        buf[i] = b'0' + (v % 10) as u8;
        v /= 10;
        if v == 0 {
            break;
        }
    }
    s.push_str(std::str::from_utf8(&buf[i..]).unwrap());
}

fn format_value(v: f32) -> String {
    if v == v.trunc() && v.abs() < 1e7 {
        format!("{}", v as i64)
    } else {
        format!("{v}")
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_basic_binary() {
        let data = "+1 1:1 5:1 9:1\n-1 2:1 3:1\n";
        let exs: Vec<Example> =
            LibsvmReader::new(data.as_bytes()).map(|e| e.unwrap()).collect();
        assert_eq!(exs.len(), 2);
        assert_eq!(exs[0].label, 1);
        assert_eq!(exs[0].indices, vec![1, 5, 9]);
        assert!(exs[0].values.is_none()); // all-ones detected as binary
        assert_eq!(exs[1].label, -1);
    }

    #[test]
    fn parse_values_and_comments() {
        let data = "# header\n\n1 3:0.5 7:2\n0 1:1\n";
        let exs: Vec<Example> =
            LibsvmReader::new(data.as_bytes()).map(|e| e.unwrap()).collect();
        assert_eq!(exs.len(), 2);
        assert_eq!(exs[0].values.as_ref().unwrap(), &[0.5, 2.0]);
        assert_eq!(exs[1].label, -1); // 0 mapped to -1
    }

    #[test]
    fn parse_unsorted_indices_normalized() {
        let data = "+1 9:1 1:1 5:1\n";
        let ex = LibsvmReader::new(data.as_bytes()).next().unwrap().unwrap();
        assert_eq!(ex.indices, vec![1, 5, 9]);
    }

    #[test]
    fn parse_errors_carry_line_numbers() {
        let data = "+1 1:1\nbogus line here\n";
        let mut rd = LibsvmReader::new(data.as_bytes());
        assert!(rd.next().unwrap().is_ok());
        let err = rd.next().unwrap().unwrap_err();
        match err {
            Error::LibsvmParse { line, .. } => assert_eq!(line, 2),
            other => panic!("wrong error {other:?}"),
        }
    }

    #[test]
    fn roundtrip_write_read() {
        let mut buf = Vec::new();
        {
            let mut w = LibsvmWriter::new(&mut buf);
            w.write_example(&Example::binary(1, vec![2, 4, 6])).unwrap();
            w.write_example(&Example {
                label: -1,
                indices: vec![1, 3],
                values: Some(vec![0.25, 4.0]),
            })
            .unwrap();
            w.finish().unwrap();
        }
        let exs: Vec<Example> =
            LibsvmReader::new(&buf[..]).map(|e| e.unwrap()).collect();
        assert_eq!(exs[0], Example::binary(1, vec![2, 4, 6]));
        assert_eq!(exs[1].values.as_ref().unwrap(), &[0.25, 4.0]);
    }

    #[test]
    fn chunked_reader_covers_everything_once() {
        let mut data = String::new();
        for i in 0..25 {
            data.push_str(&format!("+1 {}:1\n", i + 1));
        }
        let chunks: Vec<Vec<Example>> =
            ChunkedReader::new(LibsvmReader::new(data.as_bytes()), 10)
                .map(|c| c.unwrap())
                .collect();
        assert_eq!(chunks.len(), 3);
        assert_eq!(chunks[0].len(), 10);
        assert_eq!(chunks[2].len(), 5);
        let all: Vec<u32> =
            chunks.iter().flatten().map(|e| e.indices[0]).collect();
        assert_eq!(all, (1..=25).collect::<Vec<_>>());
    }

    #[test]
    fn empty_and_comment_lines_at_eof() {
        // trailing blank/comment lines must not produce a phantom example
        // or a trailing empty chunk
        let data = "+1 1:1\n-1 2:1\n\n\n# trailing comment\n\n";
        let chunks: Vec<Vec<Example>> =
            ChunkedReader::new(LibsvmReader::new(data.as_bytes()), 2)
                .map(|c| c.unwrap())
                .collect();
        assert_eq!(chunks.len(), 1);
        assert_eq!(chunks[0].len(), 2);
        // a file of only blanks/comments yields no chunks at all
        let empty = "\n# nothing\n\n";
        assert_eq!(
            ChunkedReader::new(LibsvmReader::new(empty.as_bytes()), 4).count(),
            0
        );
    }

    #[test]
    fn chunk_size_larger_than_file() {
        let data = "+1 1:1\n-1 2:1\n+1 3:1\n";
        let chunks: Vec<Vec<Example>> =
            ChunkedReader::new(LibsvmReader::new(data.as_bytes()), 1000)
                .map(|c| c.unwrap())
                .collect();
        assert_eq!(chunks.len(), 1);
        assert_eq!(chunks[0].len(), 3);
        assert_eq!(chunks[0][2].indices, vec![3]);
    }

    #[test]
    fn malformed_record_mid_stream_surfaces_line_number_through_chunks() {
        // blanks and comments before the bad record keep line numbers and
        // example counts out of sync — the error must report the *file*
        // line, and examples parsed before it must still come through
        let data = "+1 1:1\n\n# note\n-1 2:1\nbroken:record:here\n+1 4:1\n";
        let mut rd = ChunkedReader::new(LibsvmReader::new(data.as_bytes()), 2);
        let first = rd.next().unwrap().unwrap();
        assert_eq!(first.len(), 2); // the two good examples before the error
        let err = rd.next().unwrap().unwrap_err();
        match err {
            Error::LibsvmParse { line, msg } => {
                assert_eq!(line, 5, "wrong line: {msg}");
            }
            other => panic!("wrong error {other:?}"),
        }
        // a bad record *inside* a chunk surfaces the error, not a partial chunk
        let data = "+1 1:1\nbogus\n+1 2:1\n";
        let mut rd = ChunkedReader::new(LibsvmReader::new(data.as_bytes()), 10);
        let err = rd.next().unwrap().unwrap_err();
        match err {
            Error::LibsvmParse { line, .. } => assert_eq!(line, 2),
            other => panic!("wrong error {other:?}"),
        }
    }

    #[test]
    fn binary_mode_skips_values() {
        let data = "+1 3:7.5 9:2\n";
        let ex = LibsvmReader::new(data.as_bytes())
            .binary()
            .next()
            .unwrap()
            .unwrap();
        assert!(ex.values.is_none());
        assert_eq!(ex.indices, vec![3, 9]);
    }

    // ---- byte-block fast path ----
    //
    // Full byte-vs-legacy conformance (CRLF, comments, label dialects,
    // error lines across block boundaries, non-UTF8, index overflow, ...)
    // lives in `rust/tests/ingest_fastpath.rs`; the unit tests here cover
    // only what needs private access (token-parser tables, scratch
    // capacities) plus the BlockReader mechanics.

    /// Parse `data` through BlockReader + parse_block at the given slab
    /// size, collecting owned examples.
    fn byte_parse(data: &[u8], block_bytes: usize, binary: bool) -> Result<Vec<Example>> {
        let mut out = Vec::new();
        let mut parsed = ParsedChunk::default();
        for block in BlockReader::new(data).with_block_bytes(block_bytes) {
            let block = block?;
            parsed.clear();
            parse_block(&block.bytes, block.first_line, binary, &mut parsed)?;
            out.extend(parsed.to_examples());
        }
        Ok(out)
    }

    #[test]
    fn f32_bytes_matches_std_parse() {
        for tok in [
            "1", "0", "-0", "0.5", "2", "1.25", "305.2", "1e-3", "2.5E2", "-7.75",
            "+3.25", "1e10", "9999999.5", "0.0078125", "123456789012345678901",
            "1e-40", "3.4028235e38", "inf", "-inf", "nan", "1e", "", ".", "1..2",
            "4:2", "0x10",
        ] {
            let want = tok.parse::<f32>().ok();
            let got = parse_f32_bytes(tok.as_bytes());
            match (want, got) {
                (Some(w), Some(g)) => {
                    assert_eq!(w.to_bits(), g.to_bits(), "token {tok:?}: {w} vs {g}")
                }
                (None, None) => {}
                other => panic!("token {tok:?}: mismatch {other:?}"),
            }
        }
    }

    #[test]
    fn u32_bytes_matches_std_parse() {
        for tok in ["0", "1", "007", "+5", "4294967295", "4294967296", "", "+", "-1", "1a"] {
            assert_eq!(
                parse_u32_bytes(tok.as_bytes()),
                tok.parse::<u32>().ok(),
                "token {tok:?}"
            );
        }
    }

    #[test]
    fn parsed_chunk_scratch_is_reused_across_blocks() {
        let mut data = String::new();
        for i in 0..200 {
            data.push_str(&format!("+1 {}:1 {}:1 {}:1\n", i + 1, i + 500, i + 900));
        }
        let mut parsed = ParsedChunk::default();
        // warm up, then record capacities — further blocks must not grow
        parse_block(data.as_bytes(), 1, true, &mut parsed).unwrap();
        let caps =
            (parsed.labels.capacity(), parsed.indptr.capacity(), parsed.indices.capacity());
        for _ in 0..5 {
            parsed.clear();
            parse_block(data.as_bytes(), 1, true, &mut parsed).unwrap();
            assert_eq!(parsed.len(), 200);
            assert_eq!(
                (parsed.labels.capacity(), parsed.indptr.capacity(), parsed.indices.capacity()),
                caps,
                "steady-state parsing must not reallocate"
            );
        }
    }

    #[test]
    fn block_reader_recycles_buffers() {
        let mut data = String::new();
        for i in 0..500 {
            data.push_str(&format!("+1 {}:1\n", i + 1));
        }
        let (tx, rx) = std::sync::mpsc::channel();
        let mut reader = BlockReader::new(data.as_bytes()).with_block_bytes(64);
        reader.set_recycle(rx);
        let mut blocks = 0usize;
        let mut docs = 0usize;
        let mut parsed = ParsedChunk::default();
        for block in reader {
            let block = block.unwrap();
            parsed.clear();
            parse_block(&block.bytes, block.first_line, true, &mut parsed).unwrap();
            docs += parsed.len();
            blocks += 1;
            tx.send(block.bytes).unwrap(); // hand the buffer back
        }
        assert_eq!(docs, 500);
        assert!(blocks > 1, "tiny slabs must yield many blocks");
    }

    #[test]
    fn block_reader_grows_past_a_giant_line() {
        // one line far longer than the slab: the reader must grow the
        // block rather than split mid-line
        let mut data = String::from("+1");
        for i in 0..2000 {
            data.push_str(&format!(" {}:1", i + 1));
        }
        data.push_str("\n-1 5:1\n");
        let fast = byte_parse(data.as_bytes(), 16, true).unwrap();
        assert_eq!(fast.len(), 2);
        assert_eq!(fast[0].indices.len(), 2000);
        assert_eq!(fast[1].indices, vec![5]);
    }

    #[test]
    fn block_offsets_are_contiguous_and_open_at_resumes_identically() {
        let mut data = String::new();
        for i in 0..300 {
            data.push_str(&format!("+1 {}:1 {}:1\n", i + 1, i + 7));
        }
        let dir = std::env::temp_dir().join(format!("bbit_blockoff_{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("in.svm");
        std::fs::write(&path, &data).unwrap();

        let blocks: Vec<RawBlock> = BlockReader::open(&path)
            .unwrap()
            .with_block_bytes(128)
            .map(|b| b.unwrap())
            .collect();
        assert!(blocks.len() > 2);
        // offsets tile the file exactly
        let mut expect = 0u64;
        for b in &blocks {
            expect += b.bytes.len() as u64;
            assert_eq!(b.end_offset, expect);
            assert_eq!(
                b.next_line,
                b.first_line + b.bytes.iter().filter(|&&c| c == b'\n').count()
            );
        }
        assert_eq!(expect, data.len() as u64);
        // resuming from any block edge re-carves the identical tail stream
        for cut in [0usize, 1, blocks.len() / 2, blocks.len() - 1] {
            let (off, line) = if cut == 0 {
                (0, 1)
            } else {
                (blocks[cut - 1].end_offset, blocks[cut - 1].next_line)
            };
            let resumed: Vec<RawBlock> = BlockReader::open_at(&path, off, line)
                .unwrap()
                .with_block_bytes(128)
                .map(|b| b.unwrap())
                .collect();
            assert_eq!(resumed.len(), blocks.len() - cut, "cut at block {cut}");
            for (r, orig) in resumed.iter().zip(&blocks[cut..]) {
                assert_eq!(r.bytes, orig.bytes);
                assert_eq!(r.first_line, orig.first_line);
                assert_eq!(r.end_offset, orig.end_offset);
                assert_eq!(r.next_line, orig.next_line);
            }
        }
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn parse_block_lossy_quarantines_bad_lines_and_keeps_good_rows() {
        let data = b"+1 1:1\nbogus line\n-1 2:1\n+1 bad:idx:here\n+1 3:1\n";
        let mut parsed = ParsedChunk::default();
        let mut bad = Vec::new();
        parse_block_lossy(data, 1, true, &mut parsed, &mut bad);
        assert_eq!(parsed.len(), 3, "three good rows survive");
        let idx: Vec<u32> = (0..parsed.len()).map(|i| parsed.row(i).0[0]).collect();
        assert_eq!(idx, vec![1, 2, 3]);
        assert_eq!(bad.len(), 2);
        assert_eq!(bad[0].line, 2);
        assert_eq!(bad[0].bytes, b"bogus line");
        assert_eq!(bad[1].line, 4);
        assert!(!bad[1].msg.is_empty());
        // fail-fast twin errors on the same input
        let mut strict = ParsedChunk::default();
        assert!(parse_block(data, 1, true, &mut strict).is_err());
    }

    #[test]
    fn load_uses_byte_parser_and_matches_legacy_push() {
        let dir = std::env::temp_dir()
            .join(format!("bbit_libsvm_load_{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("tiny.svm");
        std::fs::write(&path, "+1 1:1 5:1\n0 2:0.25 9:4\n# c\n-1 3:1\n").unwrap();
        let ds = load(&path, 16).unwrap();
        let mut legacy = SparseDataset::new(16);
        for ex in LibsvmReader::open(&path).unwrap() {
            legacy.push(&ex.unwrap());
        }
        assert_eq!(ds.labels, legacy.labels);
        assert_eq!(ds.indptr, legacy.indptr);
        assert_eq!(ds.indices, legacy.indices);
        assert_eq!(ds.values, legacy.values);
        std::fs::remove_dir_all(&dir).ok();
    }
}
