//! Streaming LibSVM-format reader/writer.
//!
//! All the paper's datasets are "in LibSVM format", and its Table 2
//! measures *data-loading time* as the baseline every preprocessing cost is
//! compared against — so parsing speed matters and reading is fully
//! streaming (constant memory, chunked), never whole-file.
//!
//! Format per line: `<label> <idx>:<val> <idx>:<val> ...` with 1-based or
//! 0-based indices (we accept both, preserving the raw index), `+1/-1/0/1`
//! labels, `#` comments, and blank lines skipped.

use std::fs::File;
use std::io::{BufRead, BufReader, BufWriter, Read, Write};
use std::path::Path;

use crate::data::dataset::{Example, SparseDataset};
use crate::{Error, Result};

/// Streaming reader yielding one [`Example`] per data line.
pub struct LibsvmReader<R: Read> {
    lines: std::io::Lines<BufReader<R>>,
    line_no: usize,
    /// Treat all values as 1.0 and store a binary example (the paper's
    /// datasets are binary; skipping float parsing doubles throughput).
    pub binary: bool,
}

impl LibsvmReader<File> {
    pub fn open<P: AsRef<Path>>(path: P) -> Result<Self> {
        Ok(LibsvmReader::new(File::open(path)?))
    }
}

impl<R: Read> LibsvmReader<R> {
    pub fn new(inner: R) -> Self {
        LibsvmReader {
            lines: BufReader::with_capacity(1 << 20, inner).lines(),
            line_no: 0,
            binary: false,
        }
    }

    pub fn binary(mut self) -> Self {
        self.binary = true;
        self
    }

    fn parse_line(&self, line: &str) -> Result<Option<Example>> {
        let line = line.trim();
        if line.is_empty() || line.starts_with('#') {
            return Ok(None);
        }
        let mut parts = line.split_ascii_whitespace();
        let label_tok = parts.next().ok_or_else(|| Error::LibsvmParse {
            line: self.line_no,
            msg: "missing label".into(),
        })?;
        let label: i8 = match label_tok {
            "+1" | "1" => 1,
            "-1" => -1,
            "0" => -1, // some dumps use 0/1
            other => other.parse::<f32>().map(|v| if v > 0.0 { 1 } else { -1 }).map_err(
                |_| Error::LibsvmParse {
                    line: self.line_no,
                    msg: format!("bad label {other:?}"),
                },
            )?,
        };
        let mut indices = Vec::new();
        let mut values: Vec<f32> = Vec::new();
        let mut all_ones = true;
        for tok in parts {
            if tok.starts_with('#') {
                break;
            }
            let (i_str, v_str) = tok.split_once(':').ok_or_else(|| Error::LibsvmParse {
                line: self.line_no,
                msg: format!("bad feature token {tok:?}"),
            })?;
            let idx: u32 = i_str.parse().map_err(|_| Error::LibsvmParse {
                line: self.line_no,
                msg: format!("bad index {i_str:?}"),
            })?;
            indices.push(idx);
            if !self.binary {
                let v: f32 = v_str.parse().map_err(|_| Error::LibsvmParse {
                    line: self.line_no,
                    msg: format!("bad value {v_str:?}"),
                })?;
                if v != 1.0 {
                    all_ones = false;
                }
                values.push(v);
            }
        }
        // normalize: sorted unique indices (values follow their index)
        if !indices.windows(2).all(|w| w[0] < w[1]) {
            if self.binary || all_ones {
                indices.sort_unstable();
                indices.dedup();
            } else {
                let mut pairs: Vec<(u32, f32)> =
                    indices.iter().copied().zip(values.iter().copied()).collect();
                pairs.sort_unstable_by_key(|p| p.0);
                pairs.dedup_by_key(|p| p.0);
                indices = pairs.iter().map(|p| p.0).collect();
                values = pairs.iter().map(|p| p.1).collect();
            }
        }
        let values = if self.binary || all_ones { None } else { Some(values) };
        Ok(Some(Example { label, indices, values }))
    }
}

impl<R: Read> Iterator for LibsvmReader<R> {
    type Item = Result<Example>;

    fn next(&mut self) -> Option<Self::Item> {
        loop {
            self.line_no += 1;
            match self.lines.next()? {
                Err(e) => return Some(Err(e.into())),
                Ok(line) => match self.parse_line(&line) {
                    Err(e) => return Some(Err(e)),
                    Ok(Some(ex)) => return Some(Ok(ex)),
                    Ok(None) => continue, // comment/blank
                },
            }
        }
    }
}

/// Chunked streaming: yields `Vec<Example>` of at most `chunk_size` — the
/// unit of work the preprocessing pipeline shards across workers.
pub struct ChunkedReader<R: Read> {
    reader: LibsvmReader<R>,
    chunk_size: usize,
}

impl<R: Read> ChunkedReader<R> {
    pub fn new(reader: LibsvmReader<R>, chunk_size: usize) -> Self {
        assert!(chunk_size > 0);
        ChunkedReader { reader, chunk_size }
    }
}

impl<R: Read> Iterator for ChunkedReader<R> {
    type Item = Result<Vec<Example>>;

    fn next(&mut self) -> Option<Self::Item> {
        let mut chunk = Vec::with_capacity(self.chunk_size);
        for ex in self.reader.by_ref() {
            match ex {
                Ok(e) => {
                    chunk.push(e);
                    if chunk.len() == self.chunk_size {
                        return Some(Ok(chunk));
                    }
                }
                Err(e) => return Some(Err(e)),
            }
        }
        if chunk.is_empty() {
            None
        } else {
            Some(Ok(chunk))
        }
    }
}

/// Load a whole file into a [`SparseDataset`] (tests / small inputs only;
/// the pipeline path stays streaming).
pub fn load<P: AsRef<Path>>(path: P, dim: u64) -> Result<SparseDataset> {
    let mut ds = SparseDataset::new(dim);
    for ex in LibsvmReader::open(path)? {
        ds.push(&ex?);
    }
    ds.validate()?;
    Ok(ds)
}

/// Streaming writer.
pub struct LibsvmWriter<W: Write> {
    out: BufWriter<W>,
}

impl LibsvmWriter<File> {
    pub fn create<P: AsRef<Path>>(path: P) -> Result<Self> {
        Ok(LibsvmWriter::new(File::create(path)?))
    }
}

impl<W: Write> LibsvmWriter<W> {
    pub fn new(inner: W) -> Self {
        LibsvmWriter { out: BufWriter::with_capacity(1 << 20, inner) }
    }

    pub fn write_example(&mut self, ex: &Example) -> Result<()> {
        let mut line = String::with_capacity(ex.indices.len() * 12 + 4);
        line.push_str(if ex.label > 0 { "+1" } else { "-1" });
        match &ex.values {
            None => {
                for &i in &ex.indices {
                    line.push(' ');
                    push_u32(&mut line, i);
                    line.push_str(":1");
                }
            }
            Some(vals) => {
                for (&i, &v) in ex.indices.iter().zip(vals) {
                    line.push(' ');
                    push_u32(&mut line, i);
                    line.push(':');
                    line.push_str(&format_value(v));
                }
            }
        }
        line.push('\n');
        self.out.write_all(line.as_bytes())?;
        Ok(())
    }

    pub fn write_dataset(&mut self, ds: &SparseDataset) -> Result<()> {
        for ex in ds.iter() {
            self.write_example(&ex)?;
        }
        Ok(())
    }

    pub fn finish(mut self) -> Result<()> {
        self.out.flush()?;
        Ok(())
    }
}

fn push_u32(s: &mut String, v: u32) {
    let mut buf = [0u8; 10];
    let mut i = buf.len();
    let mut v = v;
    loop {
        i -= 1;
        buf[i] = b'0' + (v % 10) as u8;
        v /= 10;
        if v == 0 {
            break;
        }
    }
    s.push_str(std::str::from_utf8(&buf[i..]).unwrap());
}

fn format_value(v: f32) -> String {
    if v == v.trunc() && v.abs() < 1e7 {
        format!("{}", v as i64)
    } else {
        format!("{v}")
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_basic_binary() {
        let data = "+1 1:1 5:1 9:1\n-1 2:1 3:1\n";
        let exs: Vec<Example> =
            LibsvmReader::new(data.as_bytes()).map(|e| e.unwrap()).collect();
        assert_eq!(exs.len(), 2);
        assert_eq!(exs[0].label, 1);
        assert_eq!(exs[0].indices, vec![1, 5, 9]);
        assert!(exs[0].values.is_none()); // all-ones detected as binary
        assert_eq!(exs[1].label, -1);
    }

    #[test]
    fn parse_values_and_comments() {
        let data = "# header\n\n1 3:0.5 7:2\n0 1:1\n";
        let exs: Vec<Example> =
            LibsvmReader::new(data.as_bytes()).map(|e| e.unwrap()).collect();
        assert_eq!(exs.len(), 2);
        assert_eq!(exs[0].values.as_ref().unwrap(), &[0.5, 2.0]);
        assert_eq!(exs[1].label, -1); // 0 mapped to -1
    }

    #[test]
    fn parse_unsorted_indices_normalized() {
        let data = "+1 9:1 1:1 5:1\n";
        let ex = LibsvmReader::new(data.as_bytes()).next().unwrap().unwrap();
        assert_eq!(ex.indices, vec![1, 5, 9]);
    }

    #[test]
    fn parse_errors_carry_line_numbers() {
        let data = "+1 1:1\nbogus line here\n";
        let mut rd = LibsvmReader::new(data.as_bytes());
        assert!(rd.next().unwrap().is_ok());
        let err = rd.next().unwrap().unwrap_err();
        match err {
            Error::LibsvmParse { line, .. } => assert_eq!(line, 2),
            other => panic!("wrong error {other:?}"),
        }
    }

    #[test]
    fn roundtrip_write_read() {
        let mut buf = Vec::new();
        {
            let mut w = LibsvmWriter::new(&mut buf);
            w.write_example(&Example::binary(1, vec![2, 4, 6])).unwrap();
            w.write_example(&Example {
                label: -1,
                indices: vec![1, 3],
                values: Some(vec![0.25, 4.0]),
            })
            .unwrap();
            w.finish().unwrap();
        }
        let exs: Vec<Example> =
            LibsvmReader::new(&buf[..]).map(|e| e.unwrap()).collect();
        assert_eq!(exs[0], Example::binary(1, vec![2, 4, 6]));
        assert_eq!(exs[1].values.as_ref().unwrap(), &[0.25, 4.0]);
    }

    #[test]
    fn chunked_reader_covers_everything_once() {
        let mut data = String::new();
        for i in 0..25 {
            data.push_str(&format!("+1 {}:1\n", i + 1));
        }
        let chunks: Vec<Vec<Example>> =
            ChunkedReader::new(LibsvmReader::new(data.as_bytes()), 10)
                .map(|c| c.unwrap())
                .collect();
        assert_eq!(chunks.len(), 3);
        assert_eq!(chunks[0].len(), 10);
        assert_eq!(chunks[2].len(), 5);
        let all: Vec<u32> =
            chunks.iter().flatten().map(|e| e.indices[0]).collect();
        assert_eq!(all, (1..=25).collect::<Vec<_>>());
    }

    #[test]
    fn empty_and_comment_lines_at_eof() {
        // trailing blank/comment lines must not produce a phantom example
        // or a trailing empty chunk
        let data = "+1 1:1\n-1 2:1\n\n\n# trailing comment\n\n";
        let chunks: Vec<Vec<Example>> =
            ChunkedReader::new(LibsvmReader::new(data.as_bytes()), 2)
                .map(|c| c.unwrap())
                .collect();
        assert_eq!(chunks.len(), 1);
        assert_eq!(chunks[0].len(), 2);
        // a file of only blanks/comments yields no chunks at all
        let empty = "\n# nothing\n\n";
        assert_eq!(
            ChunkedReader::new(LibsvmReader::new(empty.as_bytes()), 4).count(),
            0
        );
    }

    #[test]
    fn chunk_size_larger_than_file() {
        let data = "+1 1:1\n-1 2:1\n+1 3:1\n";
        let chunks: Vec<Vec<Example>> =
            ChunkedReader::new(LibsvmReader::new(data.as_bytes()), 1000)
                .map(|c| c.unwrap())
                .collect();
        assert_eq!(chunks.len(), 1);
        assert_eq!(chunks[0].len(), 3);
        assert_eq!(chunks[0][2].indices, vec![3]);
    }

    #[test]
    fn malformed_record_mid_stream_surfaces_line_number_through_chunks() {
        // blanks and comments before the bad record keep line numbers and
        // example counts out of sync — the error must report the *file*
        // line, and examples parsed before it must still come through
        let data = "+1 1:1\n\n# note\n-1 2:1\nbroken:record:here\n+1 4:1\n";
        let mut rd = ChunkedReader::new(LibsvmReader::new(data.as_bytes()), 2);
        let first = rd.next().unwrap().unwrap();
        assert_eq!(first.len(), 2); // the two good examples before the error
        let err = rd.next().unwrap().unwrap_err();
        match err {
            Error::LibsvmParse { line, msg } => {
                assert_eq!(line, 5, "wrong line: {msg}");
            }
            other => panic!("wrong error {other:?}"),
        }
        // a bad record *inside* a chunk surfaces the error, not a partial chunk
        let data = "+1 1:1\nbogus\n+1 2:1\n";
        let mut rd = ChunkedReader::new(LibsvmReader::new(data.as_bytes()), 10);
        let err = rd.next().unwrap().unwrap_err();
        match err {
            Error::LibsvmParse { line, .. } => assert_eq!(line, 2),
            other => panic!("wrong error {other:?}"),
        }
    }

    #[test]
    fn binary_mode_skips_values() {
        let data = "+1 3:7.5 9:2\n";
        let ex = LibsvmReader::new(data.as_bytes())
            .binary()
            .next()
            .unwrap()
            .unwrap();
        assert!(ex.values.is_none());
        assert_eq!(ex.indices, vec![3, 9]);
    }
}
