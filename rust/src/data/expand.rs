//! Feature expansion: original + all pairwise + 1/30 of 3-way combinations.
//!
//! This is the paper's own construction for the 200 GB dataset
//! (Section 1/4: "original features + all pairwise combinations (products)
//! of features + 1/30 of the 3-way combinations").  For *binary* data a
//! product of features is their co-occurrence indicator, so expansion maps
//! a token set T to the feature set
//!
//! - unigram t            → feature id `t`                       (exact)
//! - pair (t1 < t2)       → feature id `V + pairIndex(t1, t2)`   (exact
//!   combinatorial numbering — collision-free, like the paper's explicit
//!   dimensions)
//! - triple (t1<t2<t3)    → kept iff `mix(t1,t2,t3) % 30 == 0`
//!   (deterministic 1/30 subsample), id hashed into the tail region
//!   `[V + C(V,2), D)`.
//!
//! With V = 12000 the exact regions cover 12000 + 71,994,000 ≈ 2^26.1
//! dimensions and the triple tail fills the rest of D = 2^30 — giving the
//! r = f/D → 0 regime of the paper's Eq. 5.

use crate::data::dataset::{Example, SparseDataset};

/// Expansion configuration.
#[derive(Clone, Copy, Debug)]
pub struct ExpandConfig {
    /// Base vocabulary size V (indices in input examples must be < V).
    pub vocab: u32,
    /// Target dimensionality D of the expanded space.
    pub dim: u64,
    /// Keep one out of `three_way_rate` 3-way combinations (paper: 30).
    pub three_way_rate: u32,
    /// Seed for the triple-id mixing hash.
    pub seed: u64,
}

impl ExpandConfig {
    pub fn rcv1_like(vocab: u32) -> Self {
        ExpandConfig { vocab, dim: 1 << 30, three_way_rate: 30, seed: 0x3A93 }
    }

    /// First feature id of the pairwise region.
    pub fn pair_base(&self) -> u64 {
        self.vocab as u64
    }

    /// Number of pairwise ids: C(V, 2).
    pub fn pair_count(&self) -> u64 {
        let v = self.vocab as u64;
        v * (v - 1) / 2
    }

    /// First feature id of the (hashed) 3-way region.
    pub fn triple_base(&self) -> u64 {
        self.pair_base() + self.pair_count()
    }

    /// Size of the 3-way region.
    pub fn triple_space(&self) -> u64 {
        self.dim - self.triple_base()
    }

    pub fn validate(&self) -> crate::Result<()> {
        if self.triple_base() >= self.dim {
            return Err(crate::Error::InvalidArg(format!(
                "dim {} too small for vocab {} (pairs need {})",
                self.dim,
                self.vocab,
                self.triple_base()
            )));
        }
        if self.dim > u32::MAX as u64 + 1 {
            return Err(crate::Error::InvalidArg(
                "expanded dim must fit u32 feature indices".into(),
            ));
        }
        Ok(())
    }
}

/// Exact combinatorial index of the pair (t1 < t2) in row-major order:
/// pairs (0,1), (0,2), .., (0,V−1), (1,2), ..
#[inline]
pub fn pair_index(t1: u64, t2: u64, v: u64) -> u64 {
    debug_assert!(t1 < t2 && t2 < v);
    t1 * v - t1 * (t1 + 1) / 2 + (t2 - t1 - 1)
}

/// 64-bit mix of a triple (order-sensitive; callers pass sorted triples).
#[inline]
fn mix3(t1: u32, t2: u32, t3: u32, seed: u64) -> u64 {
    let mut z = (t1 as u64) << 42 ^ (t2 as u64) << 21 ^ t3 as u64 ^ seed;
    z = (z ^ (z >> 33)).wrapping_mul(0xFF51_AFD7_ED55_8CCD);
    z = (z ^ (z >> 33)).wrapping_mul(0xC4CE_B9FE_1A85_EC53);
    z ^ (z >> 33)
}

/// Expand one example's token set into the high-dimensional feature set.
pub fn expand_example(cfg: &ExpandConfig, ex: &Example) -> Example {
    let t = &ex.indices;
    debug_assert!(t.iter().all(|&x| x < cfg.vocab));
    let l = t.len();
    let v = cfg.vocab as u64;
    let mut out: Vec<u32> =
        Vec::with_capacity(l + l * (l - 1) / 2 + l * l * l / (6 * cfg.three_way_rate as usize).max(1));
    // unigrams (region [0, V))
    out.extend_from_slice(t);
    // pairwise (exact, region [V, V + C(V,2)))
    let pair_base = cfg.pair_base();
    for i in 0..l {
        for j in (i + 1)..l {
            out.push((pair_base + pair_index(t[i] as u64, t[j] as u64, v)) as u32);
        }
    }
    // 3-way, 1/30 deterministic subsample, hashed into the tail region
    let triple_base = cfg.triple_base();
    let triple_space = cfg.triple_space();
    let rate = cfg.three_way_rate as u64;
    for i in 0..l {
        for j in (i + 1)..l {
            for k in (j + 1)..l {
                let h = mix3(t[i], t[j], t[k], cfg.seed);
                if h % rate == 0 {
                    out.push((triple_base + (h / rate) % triple_space) as u32);
                }
            }
        }
    }
    Example::binary(ex.label, out)
}

/// Expand a whole dataset (memory-resident; the pipeline does this
/// streaming, chunk by chunk).
pub fn expand_dataset(cfg: &ExpandConfig, ds: &SparseDataset) -> SparseDataset {
    let mut out = SparseDataset::new(cfg.dim);
    for ex in ds.iter() {
        out.push(&expand_example(cfg, &ex));
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn pair_index_is_a_bijection() {
        let v = 50u64;
        let mut seen = std::collections::HashSet::new();
        for t1 in 0..v {
            for t2 in (t1 + 1)..v {
                let idx = pair_index(t1, t2, v);
                assert!(idx < v * (v - 1) / 2);
                assert!(seen.insert(idx), "collision at ({t1},{t2})");
            }
        }
        assert_eq!(seen.len() as u64, v * (v - 1) / 2);
    }

    #[test]
    fn expansion_counts_match_formula() {
        let cfg = ExpandConfig { vocab: 100, dim: 1 << 20, three_way_rate: 1, seed: 1 };
        cfg.validate().unwrap();
        let ex = Example::binary(1, (0..10).collect());
        let expanded = expand_example(&cfg, &ex);
        // 10 unigrams + 45 pairs + 120 triples (rate 1 keeps all), minus
        // possible triple-hash collisions in the tail region
        assert!(expanded.nnz() >= 10 + 45 + 115 && expanded.nnz() <= 175);
    }

    #[test]
    fn three_way_rate_thins_triples() {
        let cfg30 = ExpandConfig { vocab: 200, dim: 1 << 26, three_way_rate: 30, seed: 5 };
        let cfg1 = ExpandConfig { three_way_rate: 1, ..cfg30 };
        let ex = Example::binary(1, (0..30).collect());
        let n30 = expand_example(&cfg30, &ex).nnz() as f64;
        let n1 = expand_example(&cfg1, &ex).nnz() as f64;
        let base = (30 + 435) as f64;
        let triples30 = n30 - base;
        let triples1 = n1 - base;
        // C(30,3) = 4060 triples; at rate 30 expect ~135
        assert!(triples1 > 3800.0, "{triples1}");
        assert!(triples30 > 60.0 && triples30 < 260.0, "{triples30}");
    }

    #[test]
    fn expansion_is_deterministic_and_regions_disjoint() {
        let cfg = ExpandConfig::rcv1_like(12_000);
        cfg.validate().unwrap();
        let ex = Example::binary(-1, vec![5, 17, 3000, 11_999]);
        let a = expand_example(&cfg, &ex);
        let b = expand_example(&cfg, &ex);
        assert_eq!(a, b);
        // unigrams in [0, V); pairs in [V, triple_base); triples above
        let uni = a.indices.iter().filter(|&&i| (i as u64) < cfg.pair_base()).count();
        let pairs = a
            .indices
            .iter()
            .filter(|&&i| (cfg.pair_base()..cfg.triple_base()).contains(&(i as u64)))
            .count();
        assert_eq!(uni, 4);
        assert_eq!(pairs, 6);
    }

    #[test]
    fn expanded_dataset_is_valid_and_sparser_than_dim() {
        let cfg = ExpandConfig { vocab: 500, dim: 1 << 22, three_way_rate: 30, seed: 2 };
        let base = crate::data::gen::CorpusGenerator::new(
            crate::data::gen::CorpusConfig {
                n_docs: 20,
                vocab: 500,
                zipf_alpha: 1.05,
                mean_tokens: 20.0,
                class_signal: 0.5,
                pos_fraction: 0.5,
                seed: 3,
            },
        )
        .generate();
        let big = expand_dataset(&cfg, &base);
        big.validate().unwrap();
        assert_eq!(big.len(), 20);
        let s = big.stats();
        // r = f/D must be tiny (the Eq. 5 regime)
        assert!(s.nnz_mean / cfg.dim as f64 % 1.0 < 1e-3);
        assert!(s.nnz_mean > base.stats().nnz_mean);
    }
}
