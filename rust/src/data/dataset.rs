//! In-memory sparse dataset (CSR) + the Table-1 statistics.
//!
//! The paper's data are binary (sets of feature indices); values are
//! optional so VW-hashed (real-valued) datasets reuse the same container.

use crate::util::Rng;
use crate::{Error, Result};

/// One example: a label in {−1, +1} and a sorted set of feature indices
/// (with optional real values; `None` ⇒ binary / all-ones).
#[derive(Clone, Debug, PartialEq)]
pub struct Example {
    pub label: i8,
    pub indices: Vec<u32>,
    pub values: Option<Vec<f32>>,
}

impl Example {
    pub fn binary(label: i8, mut indices: Vec<u32>) -> Self {
        indices.sort_unstable();
        indices.dedup();
        Example { label, indices, values: None }
    }

    pub fn nnz(&self) -> usize {
        self.indices.len()
    }

    /// Squared L2 norm (= nnz for binary data).
    pub fn norm_sq(&self) -> f64 {
        match &self.values {
            None => self.indices.len() as f64,
            Some(v) => v.iter().map(|x| (*x as f64) * (*x as f64)).sum(),
        }
    }
}

/// CSR sparse dataset with labels.
#[derive(Clone, Debug, Default)]
pub struct SparseDataset {
    /// Feature-space dimensionality D.
    pub dim: u64,
    pub indptr: Vec<usize>,
    pub indices: Vec<u32>,
    /// `None` ⇒ binary dataset (all values 1.0).
    pub values: Option<Vec<f32>>,
    pub labels: Vec<i8>,
}

impl SparseDataset {
    pub fn new(dim: u64) -> Self {
        SparseDataset { dim, indptr: vec![0], indices: Vec::new(), values: None, labels: Vec::new() }
    }

    pub fn len(&self) -> usize {
        self.labels.len()
    }

    pub fn is_empty(&self) -> bool {
        self.labels.is_empty()
    }

    pub fn push(&mut self, ex: &Example) {
        self.push_row(ex.label, &ex.indices, ex.values.as_deref());
    }

    /// Append one row from borrowed parts — the byte-block ingest path
    /// ([`ParsedChunk`](crate::data::libsvm::ParsedChunk) rows), which
    /// otherwise had to materialize a throwaway [`Example`] per document.
    /// Same valued-promotion semantics as [`push`](Self::push).
    pub fn push_row(&mut self, label: i8, indices: &[u32], values: Option<&[f32]>) {
        debug_assert!(indices.windows(2).all(|w| w[0] < w[1]), "indices must be sorted+unique");
        self.indices.extend_from_slice(indices);
        match (&mut self.values, values) {
            (Some(vs), Some(ev)) => vs.extend_from_slice(ev),
            (Some(vs), None) => vs.extend(std::iter::repeat(1.0).take(indices.len())),
            (None, Some(ev)) => {
                // promote to valued: backfill ones
                let mut vs = vec![1.0f32; self.indices.len() - indices.len()];
                vs.extend_from_slice(ev);
                self.values = Some(vs);
            }
            (None, None) => {}
        }
        self.indptr.push(self.indices.len());
        self.labels.push(label);
    }

    /// Append a row directly from sorted-unique `(index, value)` pairs —
    /// the pipeline's VW assembly path, which otherwise had to collect the
    /// pairs into two fresh vectors just to build a throwaway [`Example`].
    pub fn push_parts(&mut self, label: i8, parts: &[(u32, f32)]) {
        debug_assert!(
            parts.windows(2).all(|w| w[0].0 < w[1].0),
            "parts must be sorted+unique by index"
        );
        self.indices.extend(parts.iter().map(|p| p.0));
        match &mut self.values {
            Some(vs) => vs.extend(parts.iter().map(|p| p.1)),
            None => {
                if parts.iter().any(|p| p.1 != 1.0) {
                    // promote to valued: backfill ones (same as `push`)
                    let mut vs = vec![1.0f32; self.indices.len() - parts.len()];
                    vs.extend(parts.iter().map(|p| p.1));
                    self.values = Some(vs);
                }
            }
        }
        self.indptr.push(self.indices.len());
        self.labels.push(label);
    }

    pub fn from_examples(dim: u64, examples: &[Example]) -> Self {
        let mut ds = SparseDataset::new(dim);
        for ex in examples {
            ds.push(ex);
        }
        ds
    }

    /// Row accessor: (indices, values) — values empty slice for binary.
    pub fn row(&self, i: usize) -> (&[u32], Option<&[f32]>) {
        let (lo, hi) = (self.indptr[i], self.indptr[i + 1]);
        (
            &self.indices[lo..hi],
            self.values.as_ref().map(|v| &v[lo..hi]),
        )
    }

    pub fn nnz(&self, i: usize) -> usize {
        self.indptr[i + 1] - self.indptr[i]
    }

    pub fn total_nnz(&self) -> usize {
        self.indices.len()
    }

    /// Table-1 statistics: n, D, median/mean nonzeros.
    pub fn stats(&self) -> DatasetStats {
        let nnzs: Vec<f64> = (0..self.len()).map(|i| self.nnz(i) as f64).collect();
        DatasetStats {
            n: self.len(),
            dim: self.dim,
            nnz_median: crate::util::stats::median(&nnzs),
            nnz_mean: crate::util::stats::mean(&nnzs),
            pos_fraction: self.labels.iter().filter(|&&y| y > 0).count() as f64
                / self.len().max(1) as f64,
            bytes_libsvm: self.approx_libsvm_bytes(),
        }
    }

    /// Approximate on-disk LibSVM size (the "24 GB / 200 GB" numbers are in
    /// this format): label + " idx:val" per nonzero.
    pub fn approx_libsvm_bytes(&self) -> u64 {
        let mut bytes = 0u64;
        for i in 0..self.len() {
            bytes += 3; // label + newline
            let (idx, _) = self.row(i);
            for &t in idx {
                bytes += 3 + (t.max(1) as f64).log10().floor() as u64 + 1;
            }
        }
        bytes
    }

    /// Random split into (train, test) with `train_frac` of examples in
    /// train — the paper uses 50/50 for rcv1, 80/20 for webspam.
    pub fn split(&self, train_frac: f64, rng: &mut Rng) -> (SparseDataset, SparseDataset) {
        let mut order: Vec<usize> = (0..self.len()).collect();
        rng.shuffle(&mut order);
        let n_train = (self.len() as f64 * train_frac).round() as usize;
        let mut train = SparseDataset::new(self.dim);
        let mut test = SparseDataset::new(self.dim);
        if self.values.is_some() {
            train.values = Some(Vec::new());
            test.values = Some(Vec::new());
        }
        for (pos, &i) in order.iter().enumerate() {
            let (idx, vals) = self.row(i);
            let ex = Example {
                label: self.labels[i],
                indices: idx.to_vec(),
                values: vals.map(|v| v.to_vec()),
            };
            if pos < n_train {
                train.push(&ex);
            } else {
                test.push(&ex);
            }
        }
        (train, test)
    }

    /// Iterate examples (allocating per row; for streaming use `row`).
    pub fn iter(&self) -> impl Iterator<Item = Example> + '_ {
        (0..self.len()).map(move |i| {
            let (idx, vals) = self.row(i);
            Example {
                label: self.labels[i],
                indices: idx.to_vec(),
                values: vals.map(|v| v.to_vec()),
            }
        })
    }

    /// Validate CSR invariants; used by the pipeline's integrity check.
    pub fn validate(&self) -> Result<()> {
        if self.indptr.len() != self.labels.len() + 1 {
            return Err(Error::InvalidArg("indptr/labels length mismatch".into()));
        }
        if *self.indptr.last().unwrap() != self.indices.len() {
            return Err(Error::InvalidArg("indptr tail != nnz".into()));
        }
        if let Some(v) = &self.values {
            if v.len() != self.indices.len() {
                return Err(Error::InvalidArg("values/indices length mismatch".into()));
            }
        }
        for i in 0..self.len() {
            let (idx, _) = self.row(i);
            if !idx.windows(2).all(|w| w[0] < w[1]) {
                return Err(Error::InvalidArg(format!("row {i} not sorted+unique")));
            }
            if idx.last().is_some_and(|&t| t as u64 >= self.dim) {
                return Err(Error::InvalidArg(format!("row {i} index out of range")));
            }
        }
        Ok(())
    }
}

/// The Table-1 row for a dataset.
#[derive(Clone, Debug)]
pub struct DatasetStats {
    pub n: usize,
    pub dim: u64,
    pub nnz_median: f64,
    pub nnz_mean: f64,
    pub pos_fraction: f64,
    pub bytes_libsvm: u64,
}

#[cfg(test)]
mod tests {
    use super::*;

    fn toy() -> SparseDataset {
        SparseDataset::from_examples(
            100,
            &[
                Example::binary(1, vec![3, 1, 2]),
                Example::binary(-1, vec![10, 20]),
                Example::binary(1, vec![5]),
            ],
        )
    }

    #[test]
    fn push_and_row_roundtrip() {
        let ds = toy();
        assert_eq!(ds.len(), 3);
        assert_eq!(ds.row(0).0, &[1, 2, 3]);
        assert_eq!(ds.row(1).0, &[10, 20]);
        assert_eq!(ds.nnz(2), 1);
        ds.validate().unwrap();
    }

    #[test]
    fn stats_match_table1_shape() {
        let s = toy().stats();
        assert_eq!(s.n, 3);
        assert_eq!(s.dim, 100);
        assert!((s.nnz_mean - 2.0).abs() < 1e-12);
        assert_eq!(s.nnz_median, 2.0);
        assert!((s.pos_fraction - 2.0 / 3.0).abs() < 1e-12);
    }

    #[test]
    fn split_partitions_examples() {
        let mut rng = Rng::new(1);
        let mut examples = Vec::new();
        for i in 0..100u32 {
            examples.push(Example::binary(if i % 2 == 0 { 1 } else { -1 }, vec![i]));
        }
        let ds = SparseDataset::from_examples(200, &examples);
        let (tr, te) = ds.split(0.8, &mut rng);
        assert_eq!(tr.len(), 80);
        assert_eq!(te.len(), 20);
        // every original example appears exactly once across the split
        let mut seen: Vec<u32> = tr.iter().chain(te.iter()).map(|e| e.indices[0]).collect();
        seen.sort_unstable();
        assert_eq!(seen, (0..100).collect::<Vec<_>>());
    }

    #[test]
    fn push_parts_matches_push() {
        let mut by_example = SparseDataset::new(64);
        by_example.values = Some(Vec::new());
        let mut by_parts = SparseDataset::new(64);
        by_parts.values = Some(Vec::new());
        let rows: Vec<(i8, Vec<(u32, f32)>)> = vec![
            (1, vec![(2, 0.5), (7, -1.0)]),
            (-1, vec![(0, 3.0)]),
            (1, vec![]),
        ];
        for (label, pairs) in &rows {
            by_example.push(&Example {
                label: *label,
                indices: pairs.iter().map(|p| p.0).collect(),
                values: Some(pairs.iter().map(|p| p.1).collect()),
            });
            by_parts.push_parts(*label, pairs);
        }
        by_parts.validate().unwrap();
        assert_eq!(by_parts.indptr, by_example.indptr);
        assert_eq!(by_parts.indices, by_example.indices);
        assert_eq!(by_parts.values, by_example.values);
        assert_eq!(by_parts.labels, by_example.labels);
    }

    #[test]
    fn push_parts_binary_promotion() {
        let mut ds = SparseDataset::new(16);
        ds.push_parts(1, &[(1, 1.0), (5, 1.0)]);
        assert!(ds.values.is_none()); // all-ones stays binary
        ds.push_parts(-1, &[(2, 2.5)]);
        let vs = ds.values.as_ref().unwrap();
        assert_eq!(vs, &[1.0, 1.0, 2.5]); // backfilled like `push`
        ds.validate().unwrap();
    }

    #[test]
    fn valued_promotion() {
        let mut ds = SparseDataset::new(50);
        ds.push(&Example::binary(1, vec![1, 2]));
        ds.push(&Example { label: -1, indices: vec![3], values: Some(vec![2.5]) });
        let (_, vals) = ds.row(0);
        assert_eq!(vals.unwrap(), &[1.0, 1.0]);
        let (_, vals) = ds.row(1);
        assert_eq!(vals.unwrap(), &[2.5]);
        ds.validate().unwrap();
    }

    #[test]
    fn validate_rejects_bad_rows() {
        let mut ds = SparseDataset::new(5);
        ds.indptr = vec![0, 2];
        ds.indices = vec![4, 1]; // unsorted
        ds.labels = vec![1];
        assert!(ds.validate().is_err());
    }
}
