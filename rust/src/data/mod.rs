//! Datasets: sparse binary storage, LibSVM streaming IO (legacy line
//! reader + the zero-copy byte-block fast path), the rcv1-like synthetic
//! corpus generator, and the paper's feature-expansion pipeline (original
//! + pairwise + 1/30 of 3-way combinations — exactly how the authors blew
//! rcv1 up to 200 GB).

pub mod dataset;
pub mod expand;
pub mod gen;
pub mod libsvm;

pub use dataset::{DatasetStats, Example, SparseDataset};
pub use libsvm::{parse_block, BlockReader, ParsedChunk, RawBlock};
