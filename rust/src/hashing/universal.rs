//! 2-universal hashing (paper Eq. 17 and Section 7).
//!
//! `h(t) = ((c1 + c2·t) mod p) mod D` with prime `p`, `c1 ∈ [0, p)`,
//! `c2 ∈ [1, p)`.  We use the Mersenne prime `p = 2^31 − 1`, the same value
//! baked into the Pallas kernels (`python/compile/kernels/ref.py::PRIME`),
//! so rust and the AOT artifacts produce **identical** hash values — the
//! cross-layer integration tests rely on this.
//!
//! The modular reduction uses the classic Mersenne shift-add trick
//! (`x mod (2^s − 1)` via fold + conditional subtract), avoiding the
//! hardware divide on the hot path.

use crate::util::Rng;

/// The Mersenne prime 2^31 − 1 shared with the Pallas kernels.
pub const PRIME: u64 = (1 << 31) - 1;

/// Reduce `x mod (2^31 − 1)` without a divide.
///
/// Valid for any `x < 2^62` (two folds bring it under `2·p`, the final
/// conditional subtract finishes).  All callers produce
/// `c1 + c2·t ≤ (p−1) + (p−1)·(D−1) < 2^62` for `D ≤ 2^31`.
#[inline(always)]
pub fn mod_mersenne31(x: u64) -> u64 {
    // each fold: x = (x & p) + (x >> 31), strictly decreasing above p
    let x = (x & PRIME) + (x >> 31);
    let x = (x & PRIME) + (x >> 31);
    if x >= PRIME {
        x - PRIME
    } else {
        x
    }
}

/// One member of the 2-universal family.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct UniversalHash {
    pub c1: u32,
    pub c2: u32,
}

impl UniversalHash {
    /// Draw parameters uniformly: `c1 ∈ [0, p)`, `c2 ∈ [1, p)`.
    pub fn draw(rng: &mut Rng) -> Self {
        UniversalHash {
            c1: rng.range_u32(0, PRIME as u32),
            c2: rng.range_u32(1, PRIME as u32),
        }
    }

    /// `((c1 + c2·t) mod p)` — the raw hash in `[0, p)`.
    #[inline(always)]
    pub fn raw(&self, t: u32) -> u64 {
        mod_mersenne31(self.c1 as u64 + self.c2 as u64 * t as u64)
    }

    /// `h(t) = raw(t) mod d` — rehashed position in `[0, d)`.
    #[inline(always)]
    pub fn hash(&self, t: u32, d: u64) -> u64 {
        // d is a power of two in all our configurations → mask;
        // fall back to % for generality.
        if d.is_power_of_two() {
            self.raw(t) & (d - 1)
        } else {
            self.raw(t) % d
        }
    }
}

/// Four independent `(c1, c2)` chains in structure-of-arrays layout — the
/// register-blocked minwise kernel ([`hash_into`]) advances all four per
/// set element, so one pass over the set serves four hash functions (k/4
/// set streams instead of k).
///
/// [`hash_into`]: crate::hashing::minwise::MinwiseHasher::hash_into
#[derive(Clone, Copy, Debug)]
pub struct Hash4 {
    pub c1: [u64; 4],
    pub c2: [u64; 4],
}

impl Hash4 {
    /// Pack four family members (a `chunks_exact(4)` window).
    #[inline]
    pub fn pack(fns: &[UniversalHash]) -> Self {
        debug_assert_eq!(fns.len(), 4);
        Hash4 {
            c1: [fns[0].c1 as u64, fns[1].c1 as u64, fns[2].c1 as u64, fns[3].c1 as u64],
            c2: [fns[0].c2 as u64, fns[1].c2 as u64, fns[2].c2 as u64, fns[3].c2 as u64],
        }
    }

    /// Raw hashes of `t` under all four chains (`(c1 + c2·t) mod p` each)
    /// — four independent mul→fold dependency chains the CPU pipeline
    /// overlaps.
    #[inline(always)]
    pub fn raw4(&self, t: u64) -> [u64; 4] {
        [
            mod_mersenne31(self.c1[0] + self.c2[0] * t),
            mod_mersenne31(self.c1[1] + self.c2[1] * t),
            mod_mersenne31(self.c1[2] + self.c2[2] * t),
            mod_mersenne31(self.c1[3] + self.c2[3] * t),
        ]
    }
}

/// A batch of `k` independent 2-universal hash functions.  Storing the
/// whole family is 8k bytes — the paper's point (Section 7) is that this
/// replaces k permutation tables of 4·D bytes each.
#[derive(Clone, Debug)]
pub struct UniversalFamily {
    pub fns: Vec<UniversalHash>,
    pub d: u64,
}

impl UniversalFamily {
    pub fn draw(k: usize, d: u64, rng: &mut Rng) -> Self {
        UniversalFamily {
            fns: (0..k).map(|_| UniversalHash::draw(rng)).collect(),
            d,
        }
    }

    pub fn k(&self) -> usize {
        self.fns.len()
    }

    /// The (c1, c2) parameter arrays in the layout the PJRT minhash
    /// artifact expects as inputs.
    pub fn param_arrays(&self) -> (Vec<u32>, Vec<u32>) {
        (
            self.fns.iter().map(|h| h.c1).collect(),
            self.fns.iter().map(|h| h.c2).collect(),
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn mod_mersenne_matches_divide() {
        let mut rng = Rng::new(3);
        for _ in 0..100_000 {
            let x = rng.next_u64() >> 2; // < 2^62
            assert_eq!(mod_mersenne31(x), x % PRIME, "x={x}");
        }
        // boundary cases
        for x in [0, 1, PRIME - 1, PRIME, PRIME + 1, (1 << 62) - 1] {
            assert_eq!(mod_mersenne31(x), x % PRIME, "x={x}");
        }
    }

    #[test]
    fn hash_is_deterministic_and_in_range() {
        let mut rng = Rng::new(5);
        let h = UniversalHash::draw(&mut rng);
        let d = 1u64 << 30;
        for t in [0u32, 1, 12345, u32::MAX >> 2] {
            let v = h.hash(t, d);
            assert!(v < d);
            assert_eq!(v, h.hash(t, d));
        }
    }

    #[test]
    fn family_collision_rate_is_universal() {
        // For a 2-universal family, Pr[h(a) == h(b)] ≈ 1/d for a != b.
        let mut rng = Rng::new(7);
        let d = 1024u64;
        let trials = 20_000;
        let mut collisions = 0;
        for _ in 0..trials {
            let h = UniversalHash::draw(&mut rng);
            let a = rng.range_u32(0, 1 << 30);
            let b = rng.range_u32(0, 1 << 30);
            if a != b && h.hash(a, d) == h.hash(b, d) {
                collisions += 1;
            }
        }
        let rate = collisions as f64 / trials as f64;
        assert!(rate < 3.0 / d as f64, "rate {rate}");
    }

    #[test]
    fn non_power_of_two_domain() {
        let mut rng = Rng::new(11);
        let h = UniversalHash::draw(&mut rng);
        for t in 0..1000u32 {
            assert!(h.hash(t, 999) < 999);
        }
    }

    #[test]
    fn hash4_matches_scalar_raw() {
        let mut rng = Rng::new(17);
        let fam = UniversalFamily::draw(4, 1 << 20, &mut rng);
        let h4 = Hash4::pack(&fam.fns);
        for t in [0u32, 1, 999, 1 << 20, u32::MAX >> 1] {
            let v = h4.raw4(t as u64);
            for j in 0..4 {
                assert_eq!(v[j], fam.fns[j].raw(t), "t={t} j={j}");
            }
        }
    }

    #[test]
    fn param_arrays_roundtrip() {
        let mut rng = Rng::new(13);
        let fam = UniversalFamily::draw(8, 1 << 20, &mut rng);
        let (c1, c2) = fam.param_arrays();
        assert_eq!(c1.len(), 8);
        for (i, f) in fam.fns.iter().enumerate() {
            assert_eq!(c1[i], f.c1);
            assert_eq!(c2[i], f.c2);
            assert!(f.c2 >= 1);
        }
    }
}
