//! Minwise hashing and b-bit minwise hashing (paper Section 2).
//!
//! A data point is a set S ⊆ Ω = {0, .., D−1} of feature indices.  For each
//! of k hash functions (2-universal by default, true permutations for the
//! Figure 8 arm) we keep `z_j = min_{t∈S} h_j(t)`; b-bit minwise hashing
//! stores only the lowest b bits of each z_j, so a data point costs
//! `b·k` bits (the paper's `n·b·k`-bit dataset).
//!
//! The 2-universal path matches the Pallas `minhash` kernel bit-for-bit
//! (same prime, same parameter layout) — asserted by the cross-layer
//! integration test in `rust/tests/runtime_parity.rs`.

use crate::hashing::permutation::Permutation;
use crate::hashing::universal::{Hash4, UniversalFamily, UniversalHash, PRIME};
use crate::util::Rng;

/// Sentinel minwise value for an empty set: `d` itself (matches the
/// kernel's `d_space` sentinel).
#[inline]
pub fn empty_sentinel(d: u64) -> u64 {
    d
}

/// k-way minwise hasher over a 2-universal family.
#[derive(Clone, Debug)]
pub struct MinwiseHasher {
    pub family: UniversalFamily,
}

impl MinwiseHasher {
    /// Draw k independent hash functions for domain `[0, d)`.
    pub fn draw(k: usize, d: u64, rng: &mut Rng) -> Self {
        MinwiseHasher { family: UniversalFamily::draw(k, d, rng) }
    }

    pub fn k(&self) -> usize {
        self.family.k()
    }

    pub fn d(&self) -> u64 {
        self.family.d
    }

    /// Minwise-hash one set (slice of distinct indices, any order) into
    /// `out` (length k).  Empty sets get the sentinel `d`.
    ///
    /// Hot path of the whole preprocessing pipeline (Table 2), now
    /// **register-blocked**: the hash-function loop is tiled 4-wide, so
    /// each pass over the set advances 4 independent `(c1, c2)` chains
    /// ([`Hash4`]) — the set is streamed k/4 times instead of k, cutting
    /// the dominant L1/L2 traffic for the large sets the expanded corpora
    /// produce, while the four `mul → mersenne-fold → min` chains per
    /// element keep the CPU pipeline full.  Min accumulation is
    /// branchless; the k mod 4 leftover functions run the per-function
    /// unrolled loop ([`min_hash_unrolled`], 4 accumulators over the set).
    pub fn hash_into(&self, set: &[u32], out: &mut [u64]) {
        debug_assert_eq!(out.len(), self.k());
        let d = self.family.d;
        if set.is_empty() {
            out.fill(empty_sentinel(d));
            return;
        }
        if d.is_power_of_two() {
            let mask = d - 1;
            hash_tiled(&self.family.fns, set, out, |v| v & mask);
        } else {
            hash_tiled(&self.family.fns, set, out, |v| v % d);
        }
    }

    /// Allocating convenience wrapper around [`hash_into`].
    pub fn hash(&self, set: &[u32]) -> Vec<u64> {
        let mut out = vec![0; self.k()];
        self.hash_into(set, &mut out);
        out
    }
}

/// The register-blocked k-way minwise kernel body: hash functions tiled
/// 4-wide so one pass over the set serves four chains; remainder functions
/// (k mod 4) fall back to the per-function unrolled loop.  Caller
/// guarantees `set` is non-empty (minima are then always `< d`, so no
/// sentinel clamp is needed).
#[inline(always)]
fn hash_tiled(
    fns: &[UniversalHash],
    set: &[u32],
    out: &mut [u64],
    reduce: impl Fn(u64) -> u64 + Copy,
) {
    debug_assert!(!set.is_empty());
    let mut fq = fns.chunks_exact(4);
    let mut oq = out.chunks_exact_mut(4);
    for (fns4, out4) in (&mut fq).zip(&mut oq) {
        let h = Hash4::pack(fns4);
        let mut m = [u64::MAX; 4];
        for &t in set {
            let v = h.raw4(t as u64);
            m[0] = m[0].min(reduce(v[0]));
            m[1] = m[1].min(reduce(v[1]));
            m[2] = m[2].min(reduce(v[2]));
            m[3] = m[3].min(reduce(v[3]));
        }
        out4.copy_from_slice(&m);
    }
    for (h, o) in fq.remainder().iter().zip(oq.into_remainder()) {
        *o = min_hash_unrolled(set, h.c1 as u64, h.c2 as u64, reduce);
    }
}

/// Min over `reduce(mod_mersenne31(c1 + c2·t))` with 4 independent
/// accumulators *over the set* — the tail kernel for the k mod 4 hash
/// functions the 4-wide tiling leaves over.  Returns `u64::MAX` for an
/// empty set (callers clamp to the sentinel).
#[inline(always)]
fn min_hash_unrolled(set: &[u32], c1: u64, c2: u64, reduce: impl Fn(u64) -> u64) -> u64 {
    use crate::hashing::universal::mod_mersenne31;
    let mut m = [u64::MAX; 4];
    let mut chunks = set.chunks_exact(4);
    for c in &mut chunks {
        // four independent mul→fold→min chains per iteration
        let v0 = reduce(mod_mersenne31(c1 + c2 * c[0] as u64));
        let v1 = reduce(mod_mersenne31(c1 + c2 * c[1] as u64));
        let v2 = reduce(mod_mersenne31(c1 + c2 * c[2] as u64));
        let v3 = reduce(mod_mersenne31(c1 + c2 * c[3] as u64));
        m[0] = m[0].min(v0);
        m[1] = m[1].min(v1);
        m[2] = m[2].min(v2);
        m[3] = m[3].min(v3);
    }
    for &t in chunks.remainder() {
        m[0] = m[0].min(reduce(mod_mersenne31(c1 + c2 * t as u64)));
    }
    m[0].min(m[1]).min(m[2].min(m[3]))
}

/// k-way minwise hasher over true permutations (Figure 8's "ideal" arm).
pub struct PermutationMinwise<P: Permutation> {
    pub perms: Vec<P>,
}

impl<P: Permutation> PermutationMinwise<P> {
    pub fn new(perms: Vec<P>) -> Self {
        PermutationMinwise { perms }
    }

    pub fn k(&self) -> usize {
        self.perms.len()
    }

    /// Same branchless 4-accumulator min pattern as the 2-universal kernel
    /// tail: four independent `apply → min` chains per iteration instead of
    /// the naive compare-and-branch loop, so the permutation arm of the
    /// Figure-8 comparison is paced by `apply`, not by branch misses.
    pub fn hash_into(&self, set: &[u32], out: &mut [u64]) {
        debug_assert_eq!(out.len(), self.k());
        for (j, p) in self.perms.iter().enumerate() {
            let mut m = [u64::MAX; 4];
            let mut chunks = set.chunks_exact(4);
            for c in &mut chunks {
                m[0] = m[0].min(p.apply(c[0] as u64));
                m[1] = m[1].min(p.apply(c[1] as u64));
                m[2] = m[2].min(p.apply(c[2] as u64));
                m[3] = m[3].min(p.apply(c[3] as u64));
            }
            for &t in chunks.remainder() {
                m[0] = m[0].min(p.apply(t as u64));
            }
            // permuted values are < len, so only an empty set keeps MAX —
            // the clamp restores the sentinel convention
            out[j] = m[0].min(m[1]).min(m[2].min(m[3])).min(empty_sentinel(p.len()));
        }
    }

    pub fn hash(&self, set: &[u32]) -> Vec<u64> {
        let mut out = vec![0; self.k()];
        self.hash_into(set, &mut out);
        out
    }
}

/// b-bit truncation of minwise values: keep the lowest b bits (Section 2).
#[inline]
pub fn bbit_truncate(z: u64, b: u32) -> u16 {
    debug_assert!(b >= 1 && b <= 16);
    (z & ((1u64 << b) - 1)) as u16
}

/// Full b-bit minwise pipeline for one configuration (k hashes, b bits):
/// set → k minwise values → k b-bit codes.
#[derive(Clone, Debug)]
pub struct BbitMinHash {
    pub hasher: MinwiseHasher,
    pub b: u32,
}

impl BbitMinHash {
    pub fn draw(k: usize, b: u32, d: u64, rng: &mut Rng) -> Self {
        assert!((1..=16).contains(&b), "b must be in 1..=16");
        BbitMinHash { hasher: MinwiseHasher::draw(k, d, rng), b }
    }

    pub fn k(&self) -> usize {
        self.hasher.k()
    }

    /// Hash a set into b-bit codes, reusing `scratch` (length k) for the
    /// full minwise values.
    pub fn codes_into(&self, set: &[u32], scratch: &mut [u64], codes: &mut [u16]) {
        self.hasher.hash_into(set, scratch);
        for (c, &z) in codes.iter_mut().zip(scratch.iter()) {
            *c = bbit_truncate(z, self.b);
        }
    }

    pub fn codes(&self, set: &[u32]) -> Vec<u16> {
        let mut scratch = vec![0u64; self.k()];
        let mut codes = vec![0u16; self.k()];
        self.codes_into(set, &mut scratch, &mut codes);
        codes
    }
}

/// Resemblance (Jaccard) of two sorted index slices — ground truth used all
/// over the estimator tests and the variance experiment.
pub fn resemblance(a: &[u32], b: &[u32]) -> f64 {
    debug_assert!(a.windows(2).all(|w| w[0] < w[1]));
    debug_assert!(b.windows(2).all(|w| w[0] < w[1]));
    let (mut i, mut j, mut inter) = (0usize, 0usize, 0usize);
    while i < a.len() && j < b.len() {
        match a[i].cmp(&b[j]) {
            std::cmp::Ordering::Less => i += 1,
            std::cmp::Ordering::Greater => j += 1,
            std::cmp::Ordering::Equal => {
                inter += 1;
                i += 1;
                j += 1;
            }
        }
    }
    let union = a.len() + b.len() - inter;
    if union == 0 {
        return 0.0;
    }
    inter as f64 / union as f64
}

/// The largest index domain the Mersenne-31 family supports: indices must
/// stay below the prime for `h` to be 2-universal on the whole domain.
pub const MAX_DOMAIN: u64 = PRIME;

#[cfg(test)]
mod tests {
    use super::*;
    use crate::hashing::permutation::FeistelPermutation;

    #[test]
    fn minwise_is_order_invariant_set_function() {
        let mut rng = Rng::new(41);
        let h = MinwiseHasher::draw(32, 1 << 24, &mut rng);
        let mut set: Vec<u32> = rng.sample_distinct(1 << 24, 200)
            .into_iter()
            .map(|x| x as u32)
            .collect();
        let a = h.hash(&set);
        set.reverse();
        let b = h.hash(&set);
        assert_eq!(a, b);
    }

    #[test]
    fn empty_set_gets_sentinel() {
        let mut rng = Rng::new(43);
        let h = MinwiseHasher::draw(4, 1 << 20, &mut rng);
        assert!(h.hash(&[]).iter().all(|&z| z == 1 << 20));
    }

    #[test]
    fn collision_probability_is_resemblance() {
        // Pr(min collision) == R (Eq. 1), 5σ Monte-Carlo gate with
        // σ² = R(1−R)/k (Eq. 2).
        let mut rng = Rng::new(47);
        let d = 1u64 << 26;
        let k = 4096;
        let shared: Vec<u32> =
            rng.sample_distinct(d, 300).into_iter().map(|x| x as u32).collect();
        let mut s1 = shared.clone();
        let mut s2 = shared;
        s1.extend(rng.sample_distinct(d, 150).into_iter().map(|x| x as u32 + 1));
        s2.extend(rng.sample_distinct(d, 150).into_iter().map(|x| x as u32 + 2));
        s1.sort_unstable();
        s1.dedup();
        s2.sort_unstable();
        s2.dedup();
        let r = resemblance(&s1, &s2);
        let h = MinwiseHasher::draw(k, d, &mut rng);
        let (z1, z2) = (h.hash(&s1), h.hash(&s2));
        let r_hat = z1.iter().zip(&z2).filter(|(a, b)| a == b).count() as f64
            / k as f64;
        let sigma = (r * (1.0 - r) / k as f64).sqrt();
        assert!((r_hat - r).abs() < 5.0 * sigma, "r_hat {r_hat} r {r}");
    }

    #[test]
    fn bbit_codes_match_truncated_minwise() {
        let mut rng = Rng::new(53);
        let bb = BbitMinHash::draw(64, 8, 1 << 22, &mut rng);
        let set: Vec<u32> =
            rng.sample_distinct(1 << 22, 100).into_iter().map(|x| x as u32).collect();
        let full = bb.hasher.hash(&set);
        let codes = bb.codes(&set);
        for (c, z) in codes.iter().zip(full) {
            assert_eq!(*c as u64, z & 0xFF);
        }
    }

    #[test]
    fn permutation_minwise_collision_probability() {
        let mut rng = Rng::new(59);
        let d = 1u64 << 20;
        let k = 2048;
        let perms: Vec<FeistelPermutation> =
            (0..k).map(|_| FeistelPermutation::draw(d, &mut rng)).collect();
        let pm = PermutationMinwise::new(perms);
        let shared: Vec<u32> =
            rng.sample_distinct(d, 200).into_iter().map(|x| x as u32).collect();
        let mut s1 = shared.clone();
        let mut s2 = shared;
        s1.extend(rng.sample_distinct(d / 2, 100).into_iter().map(|x| x as u32));
        s2.extend(
            rng.sample_distinct(d / 2, 100)
                .into_iter()
                .map(|x| x as u32 + (d / 2) as u32),
        );
        s1.sort_unstable();
        s1.dedup();
        s2.sort_unstable();
        s2.dedup();
        let r = resemblance(&s1, &s2);
        let (z1, z2) = (pm.hash(&s1), pm.hash(&s2));
        let r_hat = z1.iter().zip(&z2).filter(|(a, b)| a == b).count() as f64
            / k as f64;
        let sigma = (r * (1.0 - r) / k as f64).sqrt();
        assert!((r_hat - r).abs() < 5.0 * sigma, "r_hat {r_hat} r {r}");
    }

    #[test]
    fn tiled_kernel_matches_naive_reference_for_every_k_remainder() {
        // the register-blocked kernel must be bit-identical to the
        // one-function-at-a-time scalar loop, for k ≡ 0..3 (mod 4), both
        // power-of-two and general domains, including empty sets
        let mut rng = Rng::new(151);
        for &d in &[1u64 << 22, (1 << 22) - 19] {
            for k in [1usize, 3, 4, 5, 7, 8, 17, 64] {
                let h = MinwiseHasher::draw(k, d, &mut rng);
                for n in [0usize, 1, 3, 4, 9, 257] {
                    let set: Vec<u32> = rng
                        .sample_distinct(d, n)
                        .into_iter()
                        .map(|x| x as u32)
                        .collect();
                    let got = h.hash(&set);
                    // scalar reference straight off the definition
                    let want: Vec<u64> = h
                        .family
                        .fns
                        .iter()
                        .map(|f| {
                            set.iter()
                                .map(|&t| f.hash(t, d))
                                .min()
                                .unwrap_or(empty_sentinel(d))
                        })
                        .collect();
                    assert_eq!(got, want, "d={d} k={k} n={n}");
                }
            }
        }
    }

    #[test]
    fn permutation_minwise_matches_naive_reference() {
        let mut rng = Rng::new(157);
        let d = 1u64 << 16;
        let perms: Vec<FeistelPermutation> =
            (0..7).map(|_| FeistelPermutation::draw(d, &mut rng)).collect();
        let pm = PermutationMinwise::new(perms);
        for n in [0usize, 1, 2, 3, 4, 5, 100] {
            let set: Vec<u32> =
                rng.sample_distinct(d, n).into_iter().map(|x| x as u32).collect();
            let got = pm.hash(&set);
            let want: Vec<u64> = pm
                .perms
                .iter()
                .map(|p| {
                    set.iter()
                        .map(|&t| p.apply(t as u64))
                        .min()
                        .unwrap_or(empty_sentinel(d))
                })
                .collect();
            assert_eq!(got, want, "n={n}");
        }
    }

    #[test]
    fn resemblance_basics() {
        assert_eq!(resemblance(&[], &[]), 0.0);
        assert_eq!(resemblance(&[1, 2, 3], &[1, 2, 3]), 1.0);
        assert_eq!(resemblance(&[1, 2], &[3, 4]), 0.0);
        assert!((resemblance(&[1, 2, 3], &[2, 3, 4]) - 0.5).abs() < 1e-12);
    }
}
