//! The VW hashing algorithm (paper Section 5.2, Eq. 14).
//!
//! Signed Count-Min: every feature index t is hashed to a bin
//! `bin(t) = ((a1 + a2·t) mod p) mod k` and accumulated with a ±1 sign
//! from an independent 2-universal hash (the bias-correcting `r_t` of
//! Weinberger et al., which is the `s = 1` member of the sparse-projection
//! family — see Eq. 16 and the discussion around it).
//!
//! For the paper's binary data the hashed vector is
//! `g_j = Σ_{t∈S} sign(t)·1{bin(t) = j}`.  The generalized `s ≥ 1` variant
//! (used by the variance experiment to demonstrate the non-vanishing
//! `(s−1)Σu²u²` term) drops elements with probability `1 − 1/s` and scales
//! survivors by √s, exactly Eq. 11 applied per-coordinate.
//!
//! Matches the Pallas `vw` kernel bit-for-bit on the s = 1 path (same
//! prime, same parameter layout).

use crate::hashing::universal::{mod_mersenne31, UniversalHash};
use crate::util::Rng;

/// VW feature hasher with `k` bins.
#[derive(Clone, Debug)]
pub struct VwHasher {
    pub bin_hash: UniversalHash,
    pub sign_hash: UniversalHash,
    pub bins: usize,
}

impl VwHasher {
    pub fn draw(bins: usize, rng: &mut Rng) -> Self {
        assert!(bins >= 1);
        VwHasher {
            bin_hash: UniversalHash::draw(rng),
            sign_hash: UniversalHash::draw(rng),
            bins,
        }
    }

    /// The (a1, a2, s1, s2) array the PJRT `vw` artifact takes as input.
    pub fn param_array(&self) -> [u32; 4] {
        [self.bin_hash.c1, self.bin_hash.c2, self.sign_hash.c1, self.sign_hash.c2]
    }

    #[inline]
    pub fn bin(&self, t: u32) -> usize {
        (self.bin_hash.raw(t) % self.bins as u64) as usize
    }

    #[inline]
    pub fn sign(&self, t: u32) -> f32 {
        // even raw hash → +1, odd → −1 (matches the kernel)
        if self.sign_hash.raw(t) & 1 == 0 {
            1.0
        } else {
            -1.0
        }
    }

    /// Hash a binary set into a dense `k`-bin vector (accumulates into
    /// `out`, which must be zeroed by the caller; length `bins`).
    pub fn hash_into(&self, set: &[u32], out: &mut [f32]) {
        debug_assert_eq!(out.len(), self.bins);
        let (a1, a2) = (self.bin_hash.c1 as u64, self.bin_hash.c2 as u64);
        let (s1, s2) = (self.sign_hash.c1 as u64, self.sign_hash.c2 as u64);
        for &t in set {
            let hb = (mod_mersenne31(a1 + a2 * t as u64) % self.bins as u64) as usize;
            let sg = if mod_mersenne31(s1 + s2 * t as u64) & 1 == 0 {
                1.0f32
            } else {
                -1.0f32
            };
            out[hb] += sg;
        }
    }

    /// Allocating wrapper around [`hash_into`].
    pub fn hash(&self, set: &[u32]) -> Vec<f32> {
        let mut out = vec![0.0; self.bins];
        self.hash_into(set, &mut out);
        out
    }

    /// Sparse output as sorted (bin, value) pairs with zero bins dropped —
    /// what the CSR assembly in the pipeline consumes when `bins` is large.
    pub fn hash_sparse(&self, set: &[u32]) -> Vec<(u32, f32)> {
        self.hash_sparse_with(set, &mut Vec::new())
    }

    /// [`hash_sparse`](Self::hash_sparse) through caller-owned scratch:
    /// `scratch` holds the unsorted per-token pairs and is reused across
    /// documents (the encode workers keep one per chunk), so the only
    /// allocation left is the merged output row itself.  Output is
    /// identical to [`hash_sparse`](Self::hash_sparse).
    pub fn hash_sparse_with(
        &self,
        set: &[u32],
        scratch: &mut Vec<(u32, f32)>,
    ) -> Vec<(u32, f32)> {
        scratch.clear();
        scratch.reserve(set.len());
        for &t in set {
            scratch.push((self.bin(t) as u32, self.sign(t)));
        }
        scratch.sort_unstable_by_key(|p| p.0);
        let mut out: Vec<(u32, f32)> = Vec::with_capacity(scratch.len());
        for &(b, v) in scratch.iter() {
            match out.last_mut() {
                Some(last) if last.0 == b => last.1 += v,
                _ => out.push((b, v)),
            }
        }
        out.retain(|&(_, v)| v != 0.0);
        out
    }

    /// Generalized-`s` variant for *real-valued* vectors (Eq. 14 with the
    /// Eq. 11 sparse distribution): used by the variance experiment.  Each
    /// coordinate's `r_t ∈ {±√s w.p. 1/(2s), 0 w.p. 1−1/s}` is drawn
    /// deterministically from `(seed, t)`.
    pub fn hash_real_with_s(
        &self,
        items: &[(u32, f32)],
        s: f64,
        seed: u64,
    ) -> Vec<f32> {
        let mut out = vec![0.0f32; self.bins];
        for &(t, u) in items {
            let r = sparse_r(t, s, seed);
            if r != 0.0 {
                out[self.bin(t)] += u * r as f32;
            }
        }
        out
    }
}

/// The Eq.-11 random variable r_t, drawn deterministically from (t, seed):
/// ±√s each with probability 1/(2s), 0 otherwise.  s = 1 gives the ±1
/// Rademacher variable VW requires.
pub fn sparse_r(t: u32, s: f64, seed: u64) -> f64 {
    debug_assert!(s >= 1.0);
    let mut z = (t as u64).wrapping_mul(0x9E37_79B9_7F4A_7C15) ^ seed;
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
    z ^= z >> 31;
    let u = (z >> 11) as f64 * (1.0 / (1u64 << 53) as f64);
    if u < 1.0 / (2.0 * s) {
        s.sqrt()
    } else if u < 1.0 / s {
        -s.sqrt()
    } else {
        0.0
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn dense_and_sparse_agree() {
        let mut rng = Rng::new(61);
        let h = VwHasher::draw(128, &mut rng);
        let set: Vec<u32> =
            rng.sample_distinct(1 << 28, 300).into_iter().map(|x| x as u32).collect();
        let dense = h.hash(&set);
        let sparse = h.hash_sparse(&set);
        let mut from_sparse = vec![0.0f32; 128];
        for (b, v) in sparse {
            from_sparse[b as usize] = v;
        }
        assert_eq!(dense, from_sparse);
    }

    #[test]
    fn mass_conservation() {
        // each item contributes ±1 to exactly one bin
        let mut rng = Rng::new(67);
        let h = VwHasher::draw(1 << 14, &mut rng);
        let set: Vec<u32> =
            rng.sample_distinct(1 << 28, 500).into_iter().map(|x| x as u32).collect();
        let g = h.hash(&set);
        let l1: f32 = g.iter().map(|v| v.abs()).sum();
        assert!(l1 <= 500.0);
        assert_eq!(l1 as i64 % 2, 500 % 2); // cancellation removes pairs
    }

    #[test]
    fn inner_product_unbiased_over_draws() {
        // E[g1·g2] = |S1 ∩ S2| (Eq. 15); average over many parameter draws.
        let mut rng = Rng::new(71);
        let d = 1u64 << 24;
        let shared: Vec<u32> =
            rng.sample_distinct(d, 80).into_iter().map(|x| x as u32).collect();
        let mut s1 = shared.clone();
        let mut s2 = shared;
        s1.extend(rng.sample_distinct(d, 40).into_iter().map(|x| x as u32 | 1 << 25));
        s2.extend(rng.sample_distinct(d, 40).into_iter().map(|x| x as u32 | 1 << 26));
        s1.sort_unstable();
        s2.sort_unstable();
        let a_true = crate::hashing::minwise::resemblance(&s1, &s2)
            * (s1.len() + s2.len()) as f64
            / (1.0 + crate::hashing::minwise::resemblance(&s1, &s2));
        let bins = 256;
        let trials = 300;
        let mut sum = 0.0;
        for _ in 0..trials {
            let h = VwHasher::draw(bins, &mut rng);
            let (g1, g2) = (h.hash(&s1), h.hash(&s2));
            sum += g1.iter().zip(&g2).map(|(a, b)| (a * b) as f64).sum::<f64>();
        }
        let est = sum / trials as f64;
        let var = (s1.len() * s2.len()) as f64 / bins as f64 + a_true * a_true / bins as f64;
        let tol = 5.0 * (var / trials as f64).sqrt() + 1.0;
        assert!((est - a_true).abs() < tol, "est {est} true {a_true} tol {tol}");
    }

    #[test]
    fn sparse_r_distribution() {
        let s = 4.0;
        let n = 200_000u32;
        let (mut pos, mut neg, mut zero) = (0u32, 0u32, 0u32);
        for t in 0..n {
            let r = sparse_r(t, s, 99);
            if r > 0.0 {
                pos += 1;
                assert!((r - 2.0).abs() < 1e-12);
            } else if r < 0.0 {
                neg += 1;
            } else {
                zero += 1;
            }
        }
        let f = |c: u32| c as f64 / n as f64;
        assert!((f(pos) - 1.0 / 8.0).abs() < 0.01, "{}", f(pos));
        assert!((f(neg) - 1.0 / 8.0).abs() < 0.01);
        assert!((f(zero) - 0.75).abs() < 0.01);
    }

    #[test]
    fn param_array_layout_matches_kernel_convention() {
        let mut rng = Rng::new(73);
        let h = VwHasher::draw(64, &mut rng);
        let p = h.param_array();
        assert_eq!(p[0], h.bin_hash.c1);
        assert_eq!(p[1], h.bin_hash.c2);
        assert_eq!(p[2], h.sign_hash.c1);
        assert_eq!(p[3], h.sign_hash.c2);
    }
}
