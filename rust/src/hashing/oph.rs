//! One-permutation hashing (Li, Owen & Zhang 2012; densification per
//! Shrivastava & Li 2014).
//!
//! Classic k-way minwise hashing scans every nonzero of a data point k
//! times (once per hash function).  One-permutation hashing (OPH) pays for
//! a **single** universal-hash pass: the hashed space `[0, OPH_SPACE)` is
//! split into `bins` equal-width partitions, and each bin keeps the
//! minimum hashed value that landed in it.  The within-bin minima behave
//! like independent minwise samples, so `bins` plays the role of the
//! paper's k at 1/k-th of the hashing cost — the scheme that motivated the
//! open [`FeatureEncoder`](crate::encode::encoder::FeatureEncoder) API.
//!
//! Sparse data leave some bins **empty**; an empty bin carries no sample
//! and would bias the estimator.  We densify by rotation: an empty bin
//! borrows the code of the nearest non-empty bin to its right
//! (circularly), which restores an unbiased collision probability for the
//! borrowed positions (Shrivastava & Li, ICML'14).  A fully-empty set
//! (no features at all) gets the sentinel code in every bin, mirroring
//! [`empty_sentinel`](crate::hashing::minwise::empty_sentinel).
//!
//! Codes are b-bit truncations of the within-bin minima (lowest b bits of
//! the hashed value), so downstream storage/expansion is identical to
//! b-bit minwise hashing with k = `bins`: the packed-code cache, the
//! 2^b×`bins` expansion and the solvers all apply unchanged.

use crate::hashing::minwise::bbit_truncate;
use crate::hashing::universal::UniversalHash;
use crate::util::Rng;

/// The hashed space one-permutation hashing partitions: a power of two so
/// the universal hash reduces by mask, comfortably below the Mersenne
/// domain bound.
pub const OPH_SPACE: u64 = 1 << 30;

/// Per-bin sentinel for "no value landed here" during the scan.
const EMPTY: u64 = u64::MAX;

/// One-permutation hasher: a single universal hash, `bins` partitions,
/// b-bit codes.
#[derive(Clone, Debug)]
pub struct OnePermutationHasher {
    pub hash: UniversalHash,
    pub bins: usize,
    pub b: u32,
    /// Width of each partition (`ceil(OPH_SPACE / bins)`; the last bin may
    /// be narrower when `bins` does not divide the space).
    width: u64,
}

impl OnePermutationHasher {
    pub fn draw(bins: usize, b: u32, rng: &mut Rng) -> Self {
        assert!(bins >= 1, "bins must be >= 1");
        assert!((1..=16).contains(&b), "b must be in 1..=16");
        OnePermutationHasher {
            hash: UniversalHash::draw(rng),
            bins,
            b,
            width: OPH_SPACE.div_ceil(bins as u64),
        }
    }

    /// Which partition a hashed value falls in.
    #[inline]
    fn bin_of(&self, v: u64) -> usize {
        (v / self.width) as usize
    }

    /// Hash a set into `bins` b-bit codes.  `mins` is reusable scratch of
    /// length `bins` (the within-bin minima); `codes` receives the
    /// densified b-bit codes (length `bins`).
    pub fn codes_into(&self, set: &[u32], mins: &mut [u64], codes: &mut [u16]) {
        debug_assert_eq!(mins.len(), self.bins);
        debug_assert_eq!(codes.len(), self.bins);
        mins.fill(EMPTY);
        let mut non_empty = 0usize;
        for &t in set {
            let v = self.hash.hash(t, OPH_SPACE);
            let j = self.bin_of(v);
            if mins[j] == EMPTY {
                non_empty += 1;
            }
            if v < mins[j] {
                mins[j] = v;
            }
        }
        if non_empty == 0 {
            // empty set: sentinel code everywhere (OPH_SPACE truncates to 0
            // for every b <= 16, matching the minwise sentinel convention)
            codes.fill(bbit_truncate(OPH_SPACE, self.b));
            return;
        }
        // first pass: codes for occupied bins
        for (j, &m) in mins.iter().enumerate() {
            if m != EMPTY {
                codes[j] = bbit_truncate(m, self.b);
            }
        }
        if non_empty == self.bins {
            return;
        }
        // densify by rotation: each empty bin borrows the code of the
        // nearest occupied bin to its right (circular).  Seed the sweep
        // with the leftmost occupied bin's code — that is what the bins
        // right of the *last* occupied bin wrap around to — then walk
        // leftwards so every other empty bin picks up its true right
        // neighbour in O(bins) total.
        let first_occupied = (0..self.bins)
            .find(|&j| mins[j] != EMPTY)
            .expect("non_empty > 0 guarantees an occupied bin");
        let mut borrowed = codes[first_occupied];
        for j in (0..self.bins).rev() {
            if mins[j] == EMPTY {
                codes[j] = borrowed;
            } else {
                borrowed = codes[j];
            }
        }
    }

    /// Allocating convenience wrapper around [`codes_into`](Self::codes_into).
    pub fn codes(&self, set: &[u32]) -> Vec<u16> {
        let mut mins = vec![0u64; self.bins];
        let mut codes = vec![0u16; self.bins];
        self.codes_into(set, &mut mins, &mut codes);
        codes
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::hashing::minwise::resemblance;

    #[test]
    fn deterministic_and_order_invariant() {
        let mut rng = Rng::new(101);
        let h = OnePermutationHasher::draw(64, 8, &mut rng);
        let mut set: Vec<u32> =
            rng.sample_distinct(1 << 24, 300).into_iter().map(|x| x as u32).collect();
        let a = h.codes(&set);
        set.reverse();
        let b = h.codes(&set);
        assert_eq!(a, b);
        assert_eq!(a.len(), 64);
        assert!(a.iter().all(|&c| c < 256));
    }

    #[test]
    fn empty_set_gets_sentinel_codes() {
        let mut rng = Rng::new(103);
        let h = OnePermutationHasher::draw(16, 8, &mut rng);
        let codes = h.codes(&[]);
        assert!(codes.iter().all(|&c| c == bbit_truncate(OPH_SPACE, 8)));
    }

    #[test]
    fn densification_borrows_from_the_right_circularly() {
        let mut rng = Rng::new(107);
        // tiny set into many bins: most bins empty, every code must still
        // equal the code of the nearest occupied bin to its right
        let h = OnePermutationHasher::draw(32, 4, &mut rng);
        let set: Vec<u32> =
            rng.sample_distinct(1 << 24, 3).into_iter().map(|x| x as u32).collect();
        let mut mins = vec![0u64; 32];
        let mut codes = vec![0u16; 32];
        h.codes_into(&set, &mut mins, &mut codes);
        let occupied: Vec<usize> =
            (0..32).filter(|&j| mins[j] != u64::MAX).collect();
        assert!(!occupied.is_empty() && occupied.len() <= 3);
        for j in 0..32 {
            // nearest occupied bin at or after j, wrapping
            let src = (0..32)
                .map(|off| (j + off) % 32)
                .find(|jj| mins[*jj] != u64::MAX)
                .unwrap();
            assert_eq!(codes[j], bbit_truncate(mins[src], 4), "bin {j} src {src}");
        }
    }

    #[test]
    fn collision_fraction_tracks_resemblance() {
        // with bins ≪ nnz (few empty bins) the densified collision
        // probability approximates the b-bit collision probability
        // C + (1−C)·R with C = 2^−b; Monte-Carlo over independent draws.
        let mut rng = Rng::new(109);
        let d = 1u64 << 24;
        let shared: Vec<u32> =
            rng.sample_distinct(d, 400).into_iter().map(|x| x as u32).collect();
        let mut s1 = shared.clone();
        let mut s2 = shared;
        s1.extend(rng.sample_distinct(d, 200).into_iter().map(|x| x as u32 + 1));
        s2.extend(rng.sample_distinct(d, 200).into_iter().map(|x| x as u32 + 2));
        s1.sort_unstable();
        s1.dedup();
        s2.sort_unstable();
        s2.dedup();
        let r = resemblance(&s1, &s2);
        let (bins, b, trials) = (64usize, 8u32, 60usize);
        let c = 0.5f64.powi(b as i32);
        let expect = c + (1.0 - c) * r;
        let mut match_frac = 0.0;
        for _ in 0..trials {
            let h = OnePermutationHasher::draw(bins, b, &mut rng);
            let (c1, c2) = (h.codes(&s1), h.codes(&s2));
            match_frac += c1.iter().zip(&c2).filter(|(a, b)| a == b).count() as f64
                / bins as f64;
        }
        match_frac /= trials as f64;
        // generous 5σ-style gate: σ² ≈ p(1−p)/(bins·trials)
        let sigma = (expect * (1.0 - expect) / (bins * trials) as f64).sqrt();
        assert!(
            (match_frac - expect).abs() < 6.0 * sigma.max(0.01),
            "match {match_frac} expect {expect}"
        );
    }

    #[test]
    fn ragged_bins_stay_in_range() {
        // bins that do not divide OPH_SPACE: bin_of must never overflow
        let mut rng = Rng::new(113);
        let h = OnePermutationHasher::draw(7, 3, &mut rng);
        let set: Vec<u32> =
            rng.sample_distinct(1 << 20, 500).into_iter().map(|x| x as u32).collect();
        let codes = h.codes(&set);
        assert_eq!(codes.len(), 7);
        assert!(codes.iter().all(|&c| c < 8));
    }
}
