//! Random projections with the sparse `s`-family (paper Section 5.1).
//!
//! Projects a D-dim vector to k dims with `v_j = Σ_i u_i · r_{ij}` where
//! `r_{ij} ∈ {±√s w.p. 1/(2s), 0 w.p. 1−1/s}` (Eq. 11; s = 1 is the dense
//! Rademacher case, s = 3 is Achlioptas, large s is "very sparse random
//! projections").  `r_{ij}` is drawn deterministically from `(seed, i, j)`
//! so the implicit D×k matrix is never materialized — required for
//! D ≈ 2^30.
//!
//! The variance experiment (`experiments variance`) uses this module to
//! verify Eq. 13 and its identity with the VW variance (Eq. 16) at s = 1.

use crate::util::Rng;

/// Implicit D×k sparse projection matrix.
#[derive(Clone, Debug)]
pub struct RandomProjection {
    pub k: usize,
    pub s: f64,
    seed: u64,
}

impl RandomProjection {
    pub fn new(k: usize, s: f64, rng: &mut Rng) -> Self {
        assert!(s >= 1.0);
        RandomProjection { k, s, seed: rng.next_u64() }
    }

    /// Matrix entry r_{ij} (deterministic in (seed, i, j)).
    #[inline]
    pub fn entry(&self, i: u32, j: u32) -> f64 {
        let mut z = (i as u64) << 32 | j as u64;
        z ^= self.seed;
        z = (z ^ (z >> 33)).wrapping_mul(0xFF51_AFD7_ED55_8CCD);
        z = (z ^ (z >> 33)).wrapping_mul(0xC4CE_B9FE_1A85_EC53);
        z ^= z >> 33;
        let u = (z >> 11) as f64 * (1.0 / (1u64 << 53) as f64);
        let inv2s = 1.0 / (2.0 * self.s);
        if u < inv2s {
            self.s.sqrt()
        } else if u < 2.0 * inv2s {
            -self.s.sqrt()
        } else {
            0.0
        }
    }

    /// Project a sparse vector given as (index, value) pairs.
    pub fn project(&self, items: &[(u32, f32)]) -> Vec<f64> {
        let mut v = Vec::new();
        self.project_into(items, &mut v);
        v
    }

    /// [`project`](Self::project) into a caller-owned buffer (cleared and
    /// resized to `k`), so the encode workers project document after
    /// document through one dense scratch instead of allocating per row.
    pub fn project_into(&self, items: &[(u32, f32)], out: &mut Vec<f64>) {
        out.clear();
        out.resize(self.k, 0.0);
        for &(i, u) in items {
            if u == 0.0 {
                continue;
            }
            for (j, vj) in out.iter_mut().enumerate() {
                let r = self.entry(i, j as u32);
                if r != 0.0 {
                    *vj += u as f64 * r;
                }
            }
        }
    }

    /// Project a binary set (all values 1).
    pub fn project_set(&self, set: &[u32]) -> Vec<f64> {
        let mut v = Vec::new();
        self.project_set_into(set, &mut v);
        v
    }

    /// [`project_set`](Self::project_set) into a caller-owned buffer —
    /// also skips materializing the `(index, 1.0)` pair list the old path
    /// built per document (`1.0 · r == r` exactly, so output is
    /// bit-identical).
    pub fn project_set_into(&self, set: &[u32], out: &mut Vec<f64>) {
        out.clear();
        out.resize(self.k, 0.0);
        for &i in set {
            for (j, vj) in out.iter_mut().enumerate() {
                let r = self.entry(i, j as u32);
                if r != 0.0 {
                    *vj += r;
                }
            }
        }
    }
}

/// Unbiased inner-product estimator `â = (1/k) Σ v1_j v2_j` (Eq. 12).
pub fn estimate_inner_product(v1: &[f64], v2: &[f64]) -> f64 {
    debug_assert_eq!(v1.len(), v2.len());
    v1.iter().zip(v2).map(|(a, b)| a * b).sum::<f64>() / v1.len() as f64
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn entries_have_unit_variance_and_zero_mean() {
        let mut rng = Rng::new(81);
        for &s in &[1.0, 3.0, 10.0] {
            let rp = RandomProjection::new(1, s, &mut rng);
            let n = 400_000;
            let (mut sum, mut sumsq) = (0.0, 0.0);
            for i in 0..n {
                let r = rp.entry(i, 0);
                sum += r;
                sumsq += r * r;
            }
            let mean = sum / n as f64;
            let var = sumsq / n as f64 - mean * mean;
            assert!(mean.abs() < 0.02, "s={s} mean {mean}");
            assert!((var - 1.0).abs() < 0.03, "s={s} var {var}");
        }
    }

    #[test]
    fn inner_product_unbiased() {
        // E[â] = a over independent seeds (Eq. 12).
        let mut rng = Rng::new(83);
        let d = 1u64 << 20;
        let shared: Vec<u32> =
            rng.sample_distinct(d, 50).into_iter().map(|x| x as u32).collect();
        let mut s1 = shared.clone();
        let mut s2 = shared;
        s1.extend(rng.sample_distinct(d, 30).into_iter().map(|x| x as u32 | 1 << 21));
        s2.extend(rng.sample_distinct(d, 30).into_iter().map(|x| x as u32 | 1 << 22));
        let a_true = 50.0;
        let k = 64;
        let trials = 200;
        let mut sum = 0.0;
        for _ in 0..trials {
            let rp = RandomProjection::new(k, 1.0, &mut rng);
            let (v1, v2) = (rp.project_set(&s1), rp.project_set(&s2));
            sum += estimate_inner_product(&v1, &v2);
        }
        let est = sum / trials as f64;
        // Var ≈ (f1 f2 + a²)/k (Eq. 13 with s=1, binary data)
        let var = (80.0 * 80.0 + a_true * a_true) / k as f64;
        let tol = 5.0 * (var / trials as f64).sqrt();
        assert!((est - a_true).abs() < tol, "est {est} tol {tol}");
    }

    #[test]
    fn projection_is_linear() {
        let mut rng = Rng::new(89);
        let rp = RandomProjection::new(16, 3.0, &mut rng);
        let a = vec![(1u32, 1.0f32), (5, 2.0)];
        let b = vec![(1u32, 2.0f32), (9, -1.0)];
        let combined = vec![(1u32, 3.0f32), (5, 2.0), (9, -1.0)];
        let va = rp.project(&a);
        let vb = rp.project(&b);
        let vc = rp.project(&combined);
        for j in 0..16 {
            assert!((va[j] + vb[j] - vc[j]).abs() < 1e-9);
        }
    }
}
