//! Hashing substrates: everything Section 2, 5 and 7 of the paper depend on.
//!
//! - [`universal`]: 2-universal hash family `h(t) = ((c1 + c2·t) mod p) mod D`
//!   (paper Eq. 17) — the industry-standard replacement for permutations.
//! - [`permutation`]: *true* random permutations, both table-backed
//!   (Fisher–Yates) and storage-free (Feistel bijection) — the Figure 8
//!   comparator.
//! - [`minwise`]: k-way minwise hashing and b-bit truncation (Section 2).
//! - [`vw`]: the VW hashing algorithm (signed Count-Min, Eq. 14).
//! - [`oph`]: one-permutation hashing — one hash pass, `bins` partitions,
//!   rotation densification (Li–Owen–Zhang 2012).
//! - [`rp`]: random projections with the sparse `s`-family (Eq. 11).
//! - [`estimators`]: resemblance/inner-product estimators and their exact
//!   variance formulas (Eqs. 2, 3–7, 13, 16) used by the variance bench.
//! - [`lsh`]: banded LSH over the signatures — the near-duplicate /
//!   near-neighbor re-use path of Section 6.

pub mod estimators;
pub mod lsh;
pub mod minwise;
pub mod oph;
pub mod permutation;
pub mod rp;
pub mod universal;
pub mod vw;

pub use minwise::{BbitMinHash, MinwiseHasher};
pub use oph::OnePermutationHasher;
pub use universal::{UniversalHash, PRIME};
pub use vw::VwHasher;
