//! Estimators and their exact variance formulas (paper Eqs. 1–7, 12–16).
//!
//! These closed forms are what Section 5.3's "b-bit needs 10–100× less
//! storage than VW at the same variance" argument rests on; the
//! `experiments variance` harness checks every formula against Monte-Carlo
//! estimates produced by the actual hashers.

/// Eq. 2: Var(R̂_M) = R(1−R)/k — the k-permutation minwise estimator.
pub fn var_minwise(r: f64, k: usize) -> f64 {
    r * (1.0 - r) / k as f64
}

/// The A_{1,b}/A_{2,b} helper of Theorem 1 (Eq. 3), computed via
/// `exp`/`ln_1p`/`exp_m1` so the `r → 0` limit is numerically exact
/// (naive `powf` + subtraction cancels catastrophically for r ≲ 1e-8).
fn a_coeff(r: f64, b: u32) -> f64 {
    let pow = (1u64 << b) as f64;
    // (1-r)^(2^b - 1) = exp((2^b - 1)·ln(1-r))
    let log1m = (-r).ln_1p();
    let one_minus = ((pow - 1.0) * log1m).exp();
    // 1 - (1-r)^(2^b) = -expm1(2^b·ln(1-r))
    let denom = -(pow * log1m).exp_m1();
    r * one_minus / denom
}

/// Theorem 1 (Eq. 3): C_{1,b} and C_{2,b} for general sparsities
/// r1 = f1/D, r2 = f2/D.
pub fn c_coeffs(r1: f64, r2: f64, b: u32) -> (f64, f64) {
    // Degenerate fully-sparse limit (Eq. 4): both coefficients → 2^-b.
    if r1 <= 0.0 && r2 <= 0.0 {
        let c = 0.5f64.powi(b as i32);
        return (c, c);
    }
    let a1 = a_coeff(r1.max(1e-300), b);
    let a2 = a_coeff(r2.max(1e-300), b);
    let w1 = r1 / (r1 + r2);
    let w2 = r2 / (r1 + r2);
    let c1 = a1 * w2 + a2 * w1;
    let c2 = a1 * w1 + a2 * w2;
    (c1, c2)
}

/// Theorem 1 (Eq. 3): the b-bit collision probability
/// P_b = C_{1,b} + (1 − C_{2,b})·R.
pub fn p_b(r: f64, r1: f64, r2: f64, b: u32) -> f64 {
    let (c1, c2) = c_coeffs(r1, r2, b);
    c1 + (1.0 - c2) * r
}

/// Eq. 5: the sparse-data limit P_b = 2^−b + (1 − 2^−b)·R.
pub fn p_b_sparse(r: f64, b: u32) -> f64 {
    let c = 0.5f64.powi(b as i32);
    c + (1.0 - c) * r
}

/// Eq. 6: unbiased R̂_b from an empirical P̂_b.
pub fn r_hat_from_p_hat(p_hat: f64, r1: f64, r2: f64, b: u32) -> f64 {
    let (c1, c2) = c_coeffs(r1, r2, b);
    (p_hat - c1) / (1.0 - c2)
}

/// Eq. 7: Var(R̂_b) = P_b(1−P_b) / (k·(1−C_{2,b})²).
pub fn var_bbit(r: f64, r1: f64, r2: f64, b: u32, k: usize) -> f64 {
    let (c1, c2) = c_coeffs(r1, r2, b);
    let pb = c1 + (1.0 - c2) * r;
    pb * (1.0 - pb) / (k as f64 * (1.0 - c2) * (1.0 - c2))
}

/// Eq. 13: Var(â_rp,s) for random projections with the Eq.-10 family.
/// `sum_sq1 = Σu1², sum_sq2 = Σu2², a = Σu1u2, sum_prod_sq = Σu1²u2²`.
pub fn var_rp(
    sum_sq1: f64,
    sum_sq2: f64,
    a: f64,
    sum_prod_sq: f64,
    s: f64,
    k: usize,
) -> f64 {
    (sum_sq1 * sum_sq2 + a * a + (s - 3.0) * sum_prod_sq) / k as f64
}

/// Eq. 16: Var(â_vw,s); at s = 1 this reduces to Eq. 13's value
/// (`var_rp` with s = 1).
pub fn var_vw(
    sum_sq1: f64,
    sum_sq2: f64,
    a: f64,
    sum_prod_sq: f64,
    s: f64,
    k: usize,
) -> f64 {
    (s - 1.0) * sum_prod_sq
        + (sum_sq1 * sum_sq2 + a * a - 2.0 * sum_prod_sq) / k as f64
}

/// Storage (bits per data point) of b-bit minwise hashing: exactly b·k.
pub fn storage_bits_bbit(b: u32, k: usize) -> u64 {
    b as u64 * k as u64
}

/// Storage (bits per data point) of VW with `bins` dense entries stored at
/// `bits_per_entry` (the paper budgets 16 or 32; Section 5.3).
pub fn storage_bits_vw(bins: usize, bits_per_entry: u32) -> u64 {
    bins as u64 * bits_per_entry as u64
}

/// Storage ratio VW/b-bit needed for *equal variance* on resemblance
/// estimation of two binary sets — the Section 5.3 headline.  Computes the
/// k_vw for which Var(â_vw)/normalization matches Var(R̂_b) at k_b samples,
/// then compares bits.
pub fn equal_variance_storage_ratio(
    r: f64,
    f1: usize,
    f2: usize,
    b: u32,
    k_b: usize,
    bits_per_vw_entry: u32,
) -> f64 {
    let a = r / (1.0 + r) * (f1 + f2) as f64; // |S1∩S2| from R
    let target = var_bbit(r, 0.0, 0.0, b, k_b); // sparse limit
    // VW estimates a, not R; convert Var(â) to Var(R̂) via the delta
    // method on R = a/(f1+f2−a): dR/da = (f1+f2)/(f1+f2−a)².
    let denom = (f1 + f2) as f64 - a;
    let drda = (f1 + f2) as f64 / (denom * denom);
    // binary data: Σu² = f, Σu1²u2² = a
    let var_a_at = |k: f64| (f1 as f64 * f2 as f64 + a * a - 2.0 * a) / k;
    // solve var_a(k)·drda² = target  →  k = var_a(1)·drda²/target
    let k_vw = var_a_at(1.0) * drda * drda / target;
    storage_bits_vw(k_vw.ceil() as usize, bits_per_vw_entry) as f64
        / storage_bits_bbit(b, k_b) as f64
}

/// 3-way resemblance R₃ = |S1∩S2∩S3| / |S1∪S2∪S3| from full minwise
/// values (the extension of Section 2 the paper cites as [24]): the
/// minimum of a permuted union is uniform over the union, so the event
/// "all three minwise values collide" has probability exactly R₃.
/// `z1/z2/z3` are k-wide minwise vectors from the *same* hash family.
pub fn three_way_resemblance_hat(z1: &[u64], z2: &[u64], z3: &[u64]) -> f64 {
    debug_assert!(z1.len() == z2.len() && z2.len() == z3.len());
    if z1.is_empty() {
        return 0.0;
    }
    let hits = z1
        .iter()
        .zip(z2)
        .zip(z3)
        .filter(|((a, b), c)| a == b && b == c)
        .count();
    hits as f64 / z1.len() as f64
}

/// Variance of the 3-way estimator: Bernoulli with p = R₃ ⇒ R₃(1−R₃)/k.
pub fn var_three_way(r3: f64, k: usize) -> f64 {
    r3 * (1.0 - r3) / k as f64
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn sparse_limit_matches_theorem() {
        // Eq. 4: as r1, r2 → 0, C_{1,b} = C_{2,b} = 2^−b.
        for b in [1u32, 2, 4, 8, 16] {
            let (c1, c2) = c_coeffs(1e-12, 1e-12, b);
            let expect = 0.5f64.powi(b as i32);
            assert!((c1 - expect).abs() < 1e-6, "b={b} c1={c1}");
            assert!((c2 - expect).abs() < 1e-6);
            assert!((p_b(0.3, 1e-12, 1e-12, b) - p_b_sparse(0.3, b)).abs() < 1e-6);
        }
    }

    #[test]
    fn pb_monotone_in_r() {
        for b in [1u32, 4, 8] {
            let mut last = 0.0;
            for i in 0..=10 {
                let r = i as f64 / 10.0;
                let p = p_b_sparse(r, b);
                assert!(p >= last);
                last = p;
            }
            assert!((p_b_sparse(1.0, b) - 1.0).abs() < 1e-12);
        }
    }

    #[test]
    fn var_bbit_decreases_with_b_and_k() {
        let r = 0.4;
        assert!(var_bbit(r, 0.0, 0.0, 1, 100) > var_bbit(r, 0.0, 0.0, 8, 100));
        assert!(var_bbit(r, 0.0, 0.0, 4, 100) > var_bbit(r, 0.0, 0.0, 4, 1000));
    }

    #[test]
    fn vw_variance_equals_rp_at_s1() {
        // the Section 5.2 punchline
        let (f1, f2, a, spsq) = (1000.0, 800.0, 300.0, 300.0);
        for k in [10usize, 100, 1000] {
            let v_rp = var_rp(f1, f2, a, spsq, 1.0, k);
            let v_vw = var_vw(f1, f2, a, spsq, 1.0, k);
            assert!((v_rp - v_vw).abs() / v_rp < 1e-12, "k={k}");
        }
    }

    #[test]
    fn vw_variance_has_non_vanishing_term_for_s_gt_1() {
        let (f1, f2, a, spsq) = (1000.0, 800.0, 300.0, 300.0);
        let v = var_vw(f1, f2, a, spsq, 3.0, 1_000_000_000);
        assert!(v > 2.0 * spsq - 1e-9, "residual term must survive k→∞: {v}");
    }

    #[test]
    fn r_hat_inverts_p_b() {
        for b in [1u32, 2, 8] {
            for r in [0.1, 0.5, 0.9] {
                let (r1, r2) = (0.01, 0.02);
                let p = p_b(r, r1, r2, b);
                let r_back = r_hat_from_p_hat(p, r1, r2, b);
                assert!((r_back - r).abs() < 1e-10, "b={b} r={r} got {r_back}");
            }
        }
    }

    #[test]
    fn storage_ratio_is_large() {
        // Section 5.3: VW needs 10–100× (or more) the storage of b-bit
        // minwise hashing at equal variance for typical R.
        let ratio = equal_variance_storage_ratio(0.5, 4000, 4000, 8, 200, 32);
        assert!(ratio > 10.0, "ratio {ratio}");
    }

    #[test]
    fn three_way_estimator_is_unbiased() {
        use crate::hashing::minwise::MinwiseHasher;
        use crate::util::Rng;
        let mut rng = Rng::new(0x333);
        let d = 1u64 << 24;
        let core: Vec<u32> =
            rng.sample_distinct(d / 2, 120).into_iter().map(|x| x as u32).collect();
        let mut sets: Vec<Vec<u32>> = (0..3).map(|_| core.clone()).collect();
        for (i, s) in sets.iter_mut().enumerate() {
            s.extend(
                rng.sample_distinct(d / 8, 60)
                    .into_iter()
                    .map(|x| x as u32 + ((i as u32 + 1) << 27)),
            );
            s.sort_unstable();
        }
        // ground truth: |∩| = 120, |∪| = 120 + 3·60
        let r3 = 120.0 / (120.0 + 180.0) as f64;
        let k = 4096;
        let mh = MinwiseHasher::draw(k, d, &mut rng);
        let zs: Vec<Vec<u64>> = sets.iter().map(|s| mh.hash(s)).collect();
        let r3_hat = three_way_resemblance_hat(&zs[0], &zs[1], &zs[2]);
        let sigma = var_three_way(r3, k).sqrt();
        assert!((r3_hat - r3).abs() < 5.0 * sigma, "{r3_hat} vs {r3}");
        assert_eq!(three_way_resemblance_hat(&[], &[], &[]), 0.0);
    }

    #[test]
    fn var_minwise_eq2() {
        assert!((var_minwise(0.5, 100) - 0.0025).abs() < 1e-12);
        assert_eq!(var_minwise(0.0, 10), 0.0);
        assert_eq!(var_minwise(1.0, 10), 0.0);
    }
}
