//! True random permutations of Ω = {0, .., D−1} — the Figure 8 comparator.
//!
//! The paper (Section 7) contrasts *conceptual* minwise hashing, which
//! needs k full permutation mappings π_j, with the industry practice of
//! 2-universal simulation.  To run that comparison we need actual
//! permutations; two implementations:
//!
//! - [`TablePermutation`]: explicit Fisher–Yates table, exact but `4·D`
//!   bytes — the paper's "we cannot realistically store k permutations for
//!   rcv1 (D = 10^9)" is precisely this cost.
//! - [`FeistelPermutation`]: a 4-round Feistel network over the smallest
//!   power-of-four domain ≥ D with cycle-walking, giving a keyed bijection
//!   on `[0, D)` in O(1) memory.  This is how we make the "true
//!   permutation" arm *feasible at rcv1 scale*, documented as a
//!   substitution in DESIGN.md §5.

use crate::util::Rng;

/// A bijection on `[0, len)`.
pub trait Permutation {
    fn len(&self) -> u64;
    fn is_empty(&self) -> bool {
        self.len() == 0
    }
    /// π(t); caller must ensure `t < len`.
    fn apply(&self, t: u64) -> u64;
}

/// Explicit permutation table (Fisher–Yates).  Memory: 4·D bytes (u32).
pub struct TablePermutation {
    table: Vec<u32>,
}

impl TablePermutation {
    /// Build a uniform random permutation of `[0, d)`; `d ≤ 2^32`.
    pub fn draw(d: u64, rng: &mut Rng) -> Self {
        assert!(d <= u32::MAX as u64 + 1, "table permutation domain too large");
        let mut table: Vec<u32> = (0..d as u32).collect();
        rng.shuffle(&mut table);
        TablePermutation { table }
    }
}

impl Permutation for TablePermutation {
    fn len(&self) -> u64 {
        self.table.len() as u64
    }

    #[inline]
    fn apply(&self, t: u64) -> u64 {
        self.table[t as usize] as u64
    }
}

/// Storage-free keyed bijection: balanced 4-round Feistel over 2^(2m) ≥ D
/// with cycle-walking back into `[0, D)`.
///
/// Four rounds of a Feistel network with independent round functions are a
/// pseudorandom permutation (Luby–Rackoff); for the statistical purposes of
/// minwise hashing this is indistinguishable from a uniform permutation
/// while costing 32 bytes instead of 4·D.
pub struct FeistelPermutation {
    d: u64,
    half_bits: u32,
    keys: [u64; 4],
}

impl FeistelPermutation {
    pub fn draw(d: u64, rng: &mut Rng) -> Self {
        assert!(d >= 2 && d <= 1 << 62);
        // smallest even bit-width 2m with 2^(2m) >= d
        let bits = 64 - (d - 1).leading_zeros();
        let half_bits = bits.div_ceil(2);
        FeistelPermutation {
            d,
            half_bits,
            keys: [
                rng.next_u64(),
                rng.next_u64(),
                rng.next_u64(),
                rng.next_u64(),
            ],
        }
    }

    #[inline]
    fn round(&self, r: u64, key: u64) -> u64 {
        // 64-bit mix (splitmix finalizer) of (r, key), truncated to a half
        let mut z = r ^ key;
        z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
        z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
        (z ^ (z >> 31)) & ((1 << self.half_bits) - 1)
    }

    #[inline]
    fn encrypt_once(&self, x: u64) -> u64 {
        let mask = (1u64 << self.half_bits) - 1;
        let mut l = x >> self.half_bits;
        let mut r = x & mask;
        for &key in &self.keys {
            let (nl, nr) = (r, l ^ self.round(r, key));
            l = nl;
            r = nr;
        }
        (l << self.half_bits) | r
    }
}

impl Permutation for FeistelPermutation {
    fn len(&self) -> u64 {
        self.d
    }

    #[inline]
    fn apply(&self, t: u64) -> u64 {
        // cycle-walk: the Feistel domain is 2^(2m) ≥ d; re-encrypt until we
        // land inside [0, d). Expected iterations < 4 (domain ≤ 4·d).
        let mut x = self.encrypt_once(t);
        while x >= self.d {
            x = self.encrypt_once(x);
        }
        x
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn assert_is_permutation<P: Permutation>(p: &P) {
        let d = p.len();
        let mut seen = vec![false; d as usize];
        for t in 0..d {
            let v = p.apply(t);
            assert!(v < d, "out of range: {t} -> {v}");
            assert!(!seen[v as usize], "collision at image {v}");
            seen[v as usize] = true;
        }
    }

    #[test]
    fn table_is_a_permutation() {
        let mut rng = Rng::new(21);
        assert_is_permutation(&TablePermutation::draw(1000, &mut rng));
    }

    #[test]
    fn feistel_is_a_permutation_pow2_and_not() {
        let mut rng = Rng::new(23);
        for d in [16u64, 1000, 4096, 10_007, 1 << 16] {
            assert_is_permutation(&FeistelPermutation::draw(d, &mut rng));
        }
    }

    #[test]
    fn feistel_distinct_keys_distinct_maps() {
        let mut rng = Rng::new(29);
        let a = FeistelPermutation::draw(1 << 20, &mut rng);
        let b = FeistelPermutation::draw(1 << 20, &mut rng);
        let differs = (0..1000u64).any(|t| a.apply(t) != b.apply(t));
        assert!(differs);
    }

    #[test]
    fn feistel_min_is_roughly_uniform() {
        // min over a random 100-subset under a random permutation should be
        // ~ d/101 in expectation; check loosely over many draws.
        let mut rng = Rng::new(31);
        let d = 1u64 << 24;
        let mut mins = Vec::new();
        for _ in 0..200 {
            let p = FeistelPermutation::draw(d, &mut rng);
            let set = rng.sample_distinct(d, 100);
            let m = set.iter().map(|&t| p.apply(t)).min().unwrap();
            mins.push(m as f64);
        }
        let mean = crate::util::stats::mean(&mins);
        let expect = d as f64 / 101.0;
        assert!(
            (mean - expect).abs() < 0.35 * expect,
            "mean {mean} expect {expect}"
        );
    }
}
