//! Banded LSH over minwise signatures: near-neighbor search and
//! near-duplicate detection.
//!
//! Section 6 of the paper: *"Once the hashed data have been generated,
//! they can be used and re-used for many tasks such as supervised
//! learning, clustering, duplicate detections, near-neighbor search"* —
//! this module is that re-use path.  Classic banding (Broder'97 /
//! Indyk–Motwani): split the k-wide signature into `bands` bands of
//! `rows_per_band` values; two documents become candidates iff they agree
//! on *all* rows of at least one band.  For resemblance R the candidate
//! probability is `1 − (1 − R^r)^b` — the familiar S-curve whose threshold
//! sits near `(1/b)^(1/r)`.
//!
//! Works on full minwise values or on b-bit codes.  **b ≥ 4 is
//! recommended for banding**: two *unrelated* documents agree on a single
//! b-bit row with probability ≈ 2⁻ᵇ, so a band of `r` rows produces a
//! chance collision with probability ≈ 2⁻ᵇʳ.  At b = 1 that is ½ʳ — a
//! 4-row band fires on ~6% of random pairs and the candidate sets fill
//! with noise — while at b = 4 the same band is at ~0.02% and at b = 8
//! effectively never (`low_b_banding_floods_candidates` pins this).  Use
//! more rows per band to compensate when b must stay small.
//!
//! This module is the *offline, in-memory* form (borrowed codes, built
//! per-call).  The online form — owned shards, out-of-core build from a
//! hashed cache, on-disk snapshots, `POST /similar` — lives in
//! [`crate::similarity`] and shares the exact key mixing below
//! ([`band_key_codes`]) so both paths bucket identically.

use std::collections::HashMap;

use crate::encode::packed::PackedCodes;

/// Banding configuration.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct LshConfig {
    pub bands: usize,
    pub rows_per_band: usize,
}

impl LshConfig {
    /// Probability two documents with resemblance `r` become candidates.
    pub fn candidate_probability(&self, r: f64) -> f64 {
        1.0 - (1.0 - r.powi(self.rows_per_band as i32)).powi(self.bands as i32)
    }

    /// The S-curve threshold `(1/b)^(1/r)` — resemblance at which the
    /// candidate probability crosses ~0.5.
    pub fn threshold(&self) -> f64 {
        (1.0 / self.bands as f64).powf(1.0 / self.rows_per_band as f64)
    }

    pub fn signature_width(&self) -> usize {
        self.bands * self.rows_per_band
    }
}

/// An LSH index over b-bit code rows.
pub struct LshIndex<'a> {
    cfg: LshConfig,
    codes: &'a PackedCodes,
    /// One hash table per band: band-key → row ids.
    tables: Vec<HashMap<u64, Vec<u32>>>,
}

impl<'a> LshIndex<'a> {
    /// Build the index; `codes.k` must be ≥ `cfg.signature_width()`.
    pub fn build(codes: &'a PackedCodes, cfg: LshConfig) -> crate::Result<Self> {
        if codes.k < cfg.signature_width() {
            return Err(crate::Error::InvalidArg(format!(
                "signature needs {} codes, have k={}",
                cfg.signature_width(),
                codes.k
            )));
        }
        let mut tables: Vec<HashMap<u64, Vec<u32>>> = vec![HashMap::new(); cfg.bands];
        for row in 0..codes.n {
            for (band, table) in tables.iter_mut().enumerate() {
                let key = band_key(codes, row, band, cfg.rows_per_band);
                table.entry(key).or_default().push(row as u32);
            }
        }
        Ok(LshIndex { cfg, codes, tables })
    }

    pub fn config(&self) -> LshConfig {
        self.cfg
    }

    /// Candidate rows for a query signature (deduplicated, sorted; the
    /// query row itself is included if indexed).
    pub fn candidates_for_row(&self, row: usize) -> Vec<u32> {
        let mut out = Vec::new();
        for (band, table) in self.tables.iter().enumerate() {
            let key = band_key(self.codes, row, band, self.cfg.rows_per_band);
            if let Some(ids) = table.get(&key) {
                out.extend_from_slice(ids);
            }
        }
        out.sort_unstable();
        out.dedup();
        out
    }

    /// All near-duplicate *pairs* (i < j) whose verified code-collision
    /// fraction is ≥ `min_code_agreement` (estimating P_b of Eq. 3/5 —
    /// candidates are verified against the full signature, the standard
    /// LSH filter-then-verify step).
    pub fn near_duplicate_pairs(&self, min_code_agreement: f64) -> Vec<(u32, u32, f64)> {
        let mut seen = std::collections::HashSet::new();
        let mut out = Vec::new();
        for table in &self.tables {
            for ids in table.values() {
                if ids.len() < 2 {
                    continue;
                }
                for (a_pos, &i) in ids.iter().enumerate() {
                    for &j in &ids[a_pos + 1..] {
                        let key = ((i as u64) << 32) | j as u64;
                        if !seen.insert(key) {
                            continue;
                        }
                        let agreement = code_agreement(self.codes, i as usize, j as usize);
                        if agreement >= min_code_agreement {
                            out.push((i, j, agreement));
                        }
                    }
                }
            }
        }
        out.sort_unstable_by(|a, b| (a.0, a.1).cmp(&(b.0, b.1)));
        out
    }
}

/// Per-band FNV-flavored key seed (band index folded into the offset
/// basis so the same codes land in different buckets per band).
#[inline]
fn band_seed(band: usize) -> u64 {
    0xCBF2_9CE4_8422_2325u64 ^ (band as u64) << 32
}

/// One mixing step: fold the next code of the band into the key.
#[inline]
fn band_mix(h: u64, c: u16) -> u64 {
    (h ^ (c as u64).wrapping_add(0x9E37_79B9_7F4A_7C15)).wrapping_mul(0x100_0000_01B3)
}

/// Mix the `rows_per_band` codes of one band into a 64-bit table key.
fn band_key(codes: &PackedCodes, row: usize, band: usize, rows_per_band: usize) -> u64 {
    let mut h = band_seed(band);
    for r in 0..rows_per_band {
        h = band_mix(h, codes.get(row, band * rows_per_band + r));
    }
    h
}

/// [`band_key`] over a plain code slice — the query-side form: a signature
/// hashed on the fly (one `codes_into` row, never pushed into a
/// `PackedCodes`) buckets bit-identically to an indexed row.  This is the
/// seam [`crate::similarity`] builds on; keep the mixing in lockstep with
/// [`band_key`].
pub fn band_key_codes(sig: &[u16], band: usize, rows_per_band: usize) -> u64 {
    let mut h = band_seed(band);
    for &c in &sig[band * rows_per_band..(band + 1) * rows_per_band] {
        h = band_mix(h, c);
    }
    h
}

/// Fraction of agreeing codes between two rows — the P̂_b estimate.
pub fn code_agreement(codes: &PackedCodes, i: usize, j: usize) -> f64 {
    let hits = (0..codes.k).filter(|&q| codes.get(i, q) == codes.get(j, q)).count();
    hits as f64 / codes.k as f64
}

/// [`code_agreement`] over plain code slices (query signature vs. an
/// unpacked row) — same count, same division, so estimates from the
/// online path compare bit-for-bit against the offline one.
pub fn code_agreement_codes(a: &[u16], b: &[u16]) -> f64 {
    debug_assert_eq!(a.len(), b.len());
    let hits = a.iter().zip(b).filter(|(x, y)| x == y).count();
    hits as f64 / a.len() as f64
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::hashing::minwise::BbitMinHash;
    use crate::util::Rng;

    /// Corpus of documents where pairs (2i, 2i+1) are near-duplicates and
    /// everything else is unrelated.
    fn dup_codes(n_pairs: usize, b: u32, k: usize, seed: u64) -> PackedCodes {
        let mut rng = Rng::new(seed);
        let d = 1u64 << 24;
        let bb = BbitMinHash::draw(k, b, d, &mut rng);
        let mut pc = PackedCodes::new(b, k);
        for _ in 0..n_pairs {
            let base: Vec<u32> =
                rng.sample_distinct(d, 300).into_iter().map(|x| x as u32).collect();
            let mut near = base.clone();
            // perturb ~5% of the elements → R ≈ 0.90
            for _ in 0..15 {
                let pos = rng.below_usize(near.len());
                near[pos] = rng.below(d) as u32;
            }
            near.sort_unstable();
            near.dedup();
            pc.push_row(&bb.codes(&base)).unwrap();
            pc.push_row(&bb.codes(&near)).unwrap();
        }
        pc
    }

    #[test]
    fn s_curve_math() {
        let cfg = LshConfig { bands: 16, rows_per_band: 4 };
        assert_eq!(cfg.signature_width(), 64);
        assert!(cfg.candidate_probability(0.95) > 0.99);
        assert!(cfg.candidate_probability(0.2) < 0.05);
        let th = cfg.threshold();
        assert!((cfg.candidate_probability(th) - 0.63).abs() < 0.05); // 1-1/e
    }

    #[test]
    fn s_curve_pins_exact_values() {
        // closed-form pins: P = 1 − (1 − R^r)^b, evaluated by hand for a
        // few (bands, rows, R) points so a refactor of the formula (or an
        // i32/f64 cast slip) cannot drift unnoticed
        let cfg = LshConfig { bands: 20, rows_per_band: 5 };
        assert_eq!(cfg.signature_width(), 100);
        let pin = |r: f64| 1.0 - (1.0 - r.powi(5)).powi(20);
        for r in [0.0, 0.1, 0.5, 0.8, 0.9, 1.0] {
            assert_eq!(cfg.candidate_probability(r), pin(r), "R={r}");
        }
        assert_eq!(cfg.candidate_probability(0.0), 0.0);
        assert_eq!(cfg.candidate_probability(1.0), 1.0);
        // threshold pin: (1/20)^(1/5)
        assert!((cfg.threshold() - 0.05f64.powf(0.2)).abs() < 1e-15);
        // monotone in R
        let mut last = -1.0;
        for i in 0..=50 {
            let p = cfg.candidate_probability(i as f64 / 50.0);
            assert!(p >= last, "S-curve must be monotone");
            last = p;
        }
    }

    #[test]
    fn wide_signatures_use_only_the_banded_prefix() {
        // k larger than signature_width is fine: the index consumes only
        // the first bands·rows codes, so padding codes cannot change
        // bucketing (the mismatch direction that *is* rejected is k too
        // small — `rejects_too_narrow_signature`)
        let pc = dup_codes(5, 8, 64, 0xD3B);
        let cfg = LshConfig { bands: 8, rows_per_band: 4 }; // width 32 < k=64
        let idx = LshIndex::build(&pc, cfg).unwrap();
        // rebuild over the truncated-prefix codes: identical candidates
        let mut prefix = PackedCodes::new(8, 32);
        for row in 0..pc.n {
            prefix.push_row(&pc.row(row)[..32]).unwrap();
        }
        let idx_prefix = LshIndex::build(&prefix, cfg).unwrap();
        for row in 0..pc.n {
            assert_eq!(
                idx.candidates_for_row(row),
                idx_prefix.candidates_for_row(row),
                "row {row}"
            );
        }
    }

    #[test]
    fn band_key_codes_matches_packed_band_key() {
        let pc = dup_codes(4, 8, 64, 0xD4B);
        let r = 4;
        for row in 0..pc.n {
            let sig = pc.row(row);
            for band in 0..16 {
                assert_eq!(
                    band_key_codes(&sig, band, r),
                    band_key(&pc, row, band, r),
                    "row {row} band {band}"
                );
            }
        }
    }

    #[test]
    fn code_agreement_codes_matches_packed_form() {
        let pc = dup_codes(4, 6, 48, 0xD5B);
        for i in 0..pc.n {
            for j in 0..pc.n {
                let (a, b) = (pc.row(i), pc.row(j));
                // bit-for-bit: both are hits/k through the same f64 ops
                assert_eq!(code_agreement_codes(&a, &b), code_agreement(&pc, i, j));
            }
        }
    }

    #[test]
    fn low_b_banding_floods_candidates() {
        // the documented b ≥ 4 caveat, measured: on *unrelated* documents a
        // 4-row band chance-collides at ≈ 2^-br — ~6% per band at b=1 vs
        // ~0.02% at b=4 — so low-b candidate sets fill with noise while
        // b=4 stays clean under the identical banding config
        let n = 200usize;
        let cfg = LshConfig { bands: 16, rows_per_band: 4 };
        let mut spurious = [0usize; 2];
        for (slot, b) in [(0usize, 1u32), (1usize, 4u32)] {
            let mut rng = Rng::new(0xD6B);
            let d = 1u64 << 24;
            let bb = BbitMinHash::draw(64, b, d, &mut rng);
            let mut pc = PackedCodes::new(b, 64);
            for _ in 0..n {
                let doc: Vec<u32> =
                    rng.sample_distinct(d, 300).into_iter().map(|x| x as u32).collect();
                pc.push_row(&bb.codes(&doc)).unwrap();
            }
            let idx = LshIndex::build(&pc, cfg).unwrap();
            // candidates beyond self are all spurious (docs are unrelated)
            spurious[slot] = (0..n).map(|r| idx.candidates_for_row(r).len() - 1).sum();
        }
        assert!(
            spurious[0] > 50 * (spurious[1] + 1),
            "b=1 banding should drown in chance collisions vs b=4 \
             (got {} vs {})",
            spurious[0],
            spurious[1]
        );
        // b=1 fires on most pairs (P ≈ 1−(1−2⁻⁴)¹⁶ ≈ 0.64); b=4 stays at
        // the expected-handful level (≈ 16·16⁻⁴ per pair)
        assert!(spurious[0] > n * n / 4, "b=1 spurious {} too low", spurious[0]);
        assert!(spurious[1] < n, "b=4 spurious {} too high", spurious[1]);
    }

    #[test]
    fn finds_planted_duplicates_with_few_false_positives() {
        let k = 64;
        let pc = dup_codes(25, 8, k, 0xD0B);
        let cfg = LshConfig { bands: 16, rows_per_band: 4 };
        let idx = LshIndex::build(&pc, cfg).unwrap();
        let pairs = idx.near_duplicate_pairs(0.6);
        // every planted pair found…
        for i in 0..25u32 {
            assert!(
                pairs.iter().any(|&(a, b, _)| (a, b) == (2 * i, 2 * i + 1)),
                "missing planted pair {i}"
            );
        }
        // …and nothing else (verification step kills chance candidates)
        assert_eq!(pairs.len(), 25, "{pairs:?}");
        for &(_, _, agreement) in &pairs {
            assert!(agreement > 0.6);
        }
    }

    #[test]
    fn candidates_include_self_and_duplicate() {
        let pc = dup_codes(5, 8, 64, 0xD1B);
        let idx =
            LshIndex::build(&pc, LshConfig { bands: 16, rows_per_band: 4 }).unwrap();
        let cands = idx.candidates_for_row(0);
        assert!(cands.contains(&0));
        assert!(cands.contains(&1), "near-duplicate must be a candidate");
    }

    #[test]
    fn rejects_too_narrow_signature() {
        let pc = dup_codes(2, 8, 16, 1);
        assert!(LshIndex::build(&pc, LshConfig { bands: 8, rows_per_band: 4 }).is_err());
    }

    #[test]
    fn code_agreement_estimates_pb() {
        // agreement between unrelated rows ≈ 2^-b (Eq. 5 with R = 0)
        let pc = dup_codes(50, 4, 64, 0xD2B);
        let mut total = 0.0;
        let mut count = 0;
        for i in (0..100).step_by(2) {
            for j in ((i + 2)..100).step_by(2) {
                total += code_agreement(&pc, i, j);
                count += 1;
            }
        }
        let mean = total / count as f64;
        assert!((mean - 1.0 / 16.0).abs() < 0.02, "{mean}");
    }
}
