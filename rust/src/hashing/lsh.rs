//! Banded LSH over minwise signatures: near-neighbor search and
//! near-duplicate detection.
//!
//! Section 6 of the paper: *"Once the hashed data have been generated,
//! they can be used and re-used for many tasks such as supervised
//! learning, clustering, duplicate detections, near-neighbor search"* —
//! this module is that re-use path.  Classic banding (Broder'97 /
//! Indyk–Motwani): split the k-wide signature into `bands` bands of
//! `rows_per_band` values; two documents become candidates iff they agree
//! on *all* rows of at least one band.  For resemblance R the candidate
//! probability is `1 − (1 − R^r)^b` — the familiar S-curve whose threshold
//! sits near `(1/b)^(1/r)`.
//!
//! Works on full minwise values or on b-bit codes (b ≥ 4 recommended for
//! banding: 1-bit rows collide randomly half the time, so use more rows).

use std::collections::HashMap;

use crate::encode::packed::PackedCodes;

/// Banding configuration.
#[derive(Clone, Copy, Debug)]
pub struct LshConfig {
    pub bands: usize,
    pub rows_per_band: usize,
}

impl LshConfig {
    /// Probability two documents with resemblance `r` become candidates.
    pub fn candidate_probability(&self, r: f64) -> f64 {
        1.0 - (1.0 - r.powi(self.rows_per_band as i32)).powi(self.bands as i32)
    }

    /// The S-curve threshold `(1/b)^(1/r)` — resemblance at which the
    /// candidate probability crosses ~0.5.
    pub fn threshold(&self) -> f64 {
        (1.0 / self.bands as f64).powf(1.0 / self.rows_per_band as f64)
    }

    pub fn signature_width(&self) -> usize {
        self.bands * self.rows_per_band
    }
}

/// An LSH index over b-bit code rows.
pub struct LshIndex<'a> {
    cfg: LshConfig,
    codes: &'a PackedCodes,
    /// One hash table per band: band-key → row ids.
    tables: Vec<HashMap<u64, Vec<u32>>>,
}

impl<'a> LshIndex<'a> {
    /// Build the index; `codes.k` must be ≥ `cfg.signature_width()`.
    pub fn build(codes: &'a PackedCodes, cfg: LshConfig) -> crate::Result<Self> {
        if codes.k < cfg.signature_width() {
            return Err(crate::Error::InvalidArg(format!(
                "signature needs {} codes, have k={}",
                cfg.signature_width(),
                codes.k
            )));
        }
        let mut tables: Vec<HashMap<u64, Vec<u32>>> = vec![HashMap::new(); cfg.bands];
        for row in 0..codes.n {
            for (band, table) in tables.iter_mut().enumerate() {
                let key = band_key(codes, row, band, cfg.rows_per_band);
                table.entry(key).or_default().push(row as u32);
            }
        }
        Ok(LshIndex { cfg, codes, tables })
    }

    pub fn config(&self) -> LshConfig {
        self.cfg
    }

    /// Candidate rows for a query signature (deduplicated, sorted; the
    /// query row itself is included if indexed).
    pub fn candidates_for_row(&self, row: usize) -> Vec<u32> {
        let mut out = Vec::new();
        for (band, table) in self.tables.iter().enumerate() {
            let key = band_key(self.codes, row, band, self.cfg.rows_per_band);
            if let Some(ids) = table.get(&key) {
                out.extend_from_slice(ids);
            }
        }
        out.sort_unstable();
        out.dedup();
        out
    }

    /// All near-duplicate *pairs* (i < j) whose verified code-collision
    /// fraction is ≥ `min_code_agreement` (estimating P_b of Eq. 3/5 —
    /// candidates are verified against the full signature, the standard
    /// LSH filter-then-verify step).
    pub fn near_duplicate_pairs(&self, min_code_agreement: f64) -> Vec<(u32, u32, f64)> {
        let mut seen = std::collections::HashSet::new();
        let mut out = Vec::new();
        for table in &self.tables {
            for ids in table.values() {
                if ids.len() < 2 {
                    continue;
                }
                for (a_pos, &i) in ids.iter().enumerate() {
                    for &j in &ids[a_pos + 1..] {
                        let key = ((i as u64) << 32) | j as u64;
                        if !seen.insert(key) {
                            continue;
                        }
                        let agreement = code_agreement(self.codes, i as usize, j as usize);
                        if agreement >= min_code_agreement {
                            out.push((i, j, agreement));
                        }
                    }
                }
            }
        }
        out.sort_unstable_by(|a, b| (a.0, a.1).cmp(&(b.0, b.1)));
        out
    }
}

/// Mix the `rows_per_band` codes of one band into a 64-bit table key.
fn band_key(codes: &PackedCodes, row: usize, band: usize, rows_per_band: usize) -> u64 {
    let mut h = 0xCBF2_9CE4_8422_2325u64 ^ (band as u64) << 32;
    for r in 0..rows_per_band {
        let c = codes.get(row, band * rows_per_band + r) as u64;
        h ^= c.wrapping_add(0x9E37_79B9_7F4A_7C15);
        h = h.wrapping_mul(0x100_0000_01B3);
    }
    h
}

/// Fraction of agreeing codes between two rows — the P̂_b estimate.
pub fn code_agreement(codes: &PackedCodes, i: usize, j: usize) -> f64 {
    let hits = (0..codes.k).filter(|&q| codes.get(i, q) == codes.get(j, q)).count();
    hits as f64 / codes.k as f64
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::hashing::minwise::BbitMinHash;
    use crate::util::Rng;

    /// Corpus of documents where pairs (2i, 2i+1) are near-duplicates and
    /// everything else is unrelated.
    fn dup_codes(n_pairs: usize, b: u32, k: usize, seed: u64) -> PackedCodes {
        let mut rng = Rng::new(seed);
        let d = 1u64 << 24;
        let bb = BbitMinHash::draw(k, b, d, &mut rng);
        let mut pc = PackedCodes::new(b, k);
        for _ in 0..n_pairs {
            let base: Vec<u32> =
                rng.sample_distinct(d, 300).into_iter().map(|x| x as u32).collect();
            let mut near = base.clone();
            // perturb ~5% of the elements → R ≈ 0.90
            for _ in 0..15 {
                let pos = rng.below_usize(near.len());
                near[pos] = rng.below(d) as u32;
            }
            near.sort_unstable();
            near.dedup();
            pc.push_row(&bb.codes(&base)).unwrap();
            pc.push_row(&bb.codes(&near)).unwrap();
        }
        pc
    }

    #[test]
    fn s_curve_math() {
        let cfg = LshConfig { bands: 16, rows_per_band: 4 };
        assert_eq!(cfg.signature_width(), 64);
        assert!(cfg.candidate_probability(0.95) > 0.99);
        assert!(cfg.candidate_probability(0.2) < 0.05);
        let th = cfg.threshold();
        assert!((cfg.candidate_probability(th) - 0.63).abs() < 0.05); // 1-1/e
    }

    #[test]
    fn finds_planted_duplicates_with_few_false_positives() {
        let k = 64;
        let pc = dup_codes(25, 8, k, 0xD0B);
        let cfg = LshConfig { bands: 16, rows_per_band: 4 };
        let idx = LshIndex::build(&pc, cfg).unwrap();
        let pairs = idx.near_duplicate_pairs(0.6);
        // every planted pair found…
        for i in 0..25u32 {
            assert!(
                pairs.iter().any(|&(a, b, _)| (a, b) == (2 * i, 2 * i + 1)),
                "missing planted pair {i}"
            );
        }
        // …and nothing else (verification step kills chance candidates)
        assert_eq!(pairs.len(), 25, "{pairs:?}");
        for &(_, _, agreement) in &pairs {
            assert!(agreement > 0.6);
        }
    }

    #[test]
    fn candidates_include_self_and_duplicate() {
        let pc = dup_codes(5, 8, 64, 0xD1B);
        let idx =
            LshIndex::build(&pc, LshConfig { bands: 16, rows_per_band: 4 }).unwrap();
        let cands = idx.candidates_for_row(0);
        assert!(cands.contains(&0));
        assert!(cands.contains(&1), "near-duplicate must be a candidate");
    }

    #[test]
    fn rejects_too_narrow_signature() {
        let pc = dup_codes(2, 8, 16, 1);
        assert!(LshIndex::build(&pc, LshConfig { bands: 8, rows_per_band: 4 }).is_err());
    }

    #[test]
    fn code_agreement_estimates_pb() {
        // agreement between unrelated rows ≈ 2^-b (Eq. 5 with R = 0)
        let pc = dup_codes(50, 4, 64, 0xD2B);
        let mut total = 0.0;
        let mut count = 0;
        for i in (0..100).step_by(2) {
            for j in ((i + 2)..100).step_by(2) {
                total += code_agreement(&pc, i, j);
                count += 1;
            }
        }
        let mean = total / count as f64;
        assert!((mean - 1.0 / 16.0).abs() < 0.02, "{mean}");
    }
}
