//! Configuration system: typed config structs parsed from a minimal
//! TOML-subset file (`key = value` lines under `[section]` headers) and/or
//! `--key=value` CLI overrides.  Hand-rolled because the offline crate set
//! ships no serde/toml; the subset is documented in README §Configuration.

use std::collections::BTreeMap;
use std::path::Path;

use crate::{Error, Result};

/// Raw parsed config: section → key → value.
#[derive(Clone, Debug, Default)]
pub struct RawConfig {
    sections: BTreeMap<String, BTreeMap<String, String>>,
}

impl RawConfig {
    /// Parse the TOML subset: `[section]` headers, `key = value` pairs,
    /// `#` comments.  Values keep their raw string form; typed getters
    /// convert on access.
    pub fn parse(text: &str) -> Result<Self> {
        let mut cfg = RawConfig::default();
        let mut section = String::new();
        for (no, raw) in text.lines().enumerate() {
            let line = raw.split('#').next().unwrap_or("").trim();
            if line.is_empty() {
                continue;
            }
            if let Some(name) = line.strip_prefix('[').and_then(|s| s.strip_suffix(']')) {
                section = name.trim().to_string();
                cfg.sections.entry(section.clone()).or_default();
                continue;
            }
            let (k, v) = line.split_once('=').ok_or_else(|| {
                Error::Config(format!("line {}: expected key = value", no + 1))
            })?;
            cfg.sections
                .entry(section.clone())
                .or_default()
                .insert(k.trim().to_string(), v.trim().trim_matches('"').to_string());
        }
        Ok(cfg)
    }

    pub fn load<P: AsRef<Path>>(path: P) -> Result<Self> {
        Self::parse(&std::fs::read_to_string(path)?)
    }

    /// Apply a `section.key=value` override (CLI `--set`).
    pub fn set(&mut self, dotted: &str, value: &str) -> Result<()> {
        let (section, key) = dotted.split_once('.').ok_or_else(|| {
            Error::Config(format!("override {dotted:?} must be section.key"))
        })?;
        self.sections
            .entry(section.to_string())
            .or_default()
            .insert(key.to_string(), value.to_string());
        Ok(())
    }

    pub fn get(&self, section: &str, key: &str) -> Option<&str> {
        self.sections.get(section)?.get(key).map(|s| s.as_str())
    }

    fn typed<T: std::str::FromStr>(&self, section: &str, key: &str, default: T) -> Result<T> {
        match self.get(section, key) {
            None => Ok(default),
            Some(s) => s.parse().map_err(|_| {
                Error::Config(format!("bad value for {section}.{key}: {s:?}"))
            }),
        }
    }
}

/// Top-level pipeline + experiment configuration with defaults chosen so
/// `bbit-mh experiments all` finishes on a laptop.
#[derive(Clone, Debug)]
pub struct Config {
    /// Corpus scale (documents). Paper: 677,399 for rcv1.
    pub n_docs: usize,
    /// Base vocabulary before expansion.
    pub vocab: u32,
    /// Expanded dimensionality D.
    pub dim: u64,
    /// Train fraction (paper: 0.5 for rcv1, 0.8 for webspam).
    pub train_frac: f64,
    /// Hashing workers in the pipeline.
    pub workers: usize,
    /// Chunk size (documents) flowing through the pipeline.
    pub chunk_size: usize,
    /// Bounded-queue depth between pipeline stages (backpressure).
    pub queue_depth: usize,
    /// Master seed.
    pub seed: u64,
    /// Where artifacts live.
    pub artifacts_dir: String,
    /// Where experiment CSVs land.
    pub results_dir: String,
}

impl Default for Config {
    fn default() -> Self {
        Config {
            n_docs: 4000,
            vocab: 4000,
            dim: 1 << 30,
            train_frac: 0.5,
            workers: available_workers(),
            chunk_size: 256,
            queue_depth: 4,
            seed: 0xB_B17,
            artifacts_dir: "artifacts".into(),
            results_dir: "results".into(),
        }
    }
}

/// Default worker count: physical parallelism minus one for the reader.
pub fn available_workers() -> usize {
    std::thread::available_parallelism()
        .map(|n| n.get().saturating_sub(1).max(1))
        .unwrap_or(4)
}

impl Config {
    /// Build from a raw config's `[pipeline]`/`[data]` sections.
    pub fn from_raw(raw: &RawConfig) -> Result<Self> {
        let d = Config::default();
        Ok(Config {
            n_docs: raw.typed("data", "n_docs", d.n_docs)?,
            vocab: raw.typed("data", "vocab", d.vocab)?,
            dim: raw.typed("data", "dim", d.dim)?,
            train_frac: raw.typed("data", "train_frac", d.train_frac)?,
            workers: raw.typed("pipeline", "workers", d.workers)?,
            chunk_size: raw.typed("pipeline", "chunk_size", d.chunk_size)?,
            queue_depth: raw.typed("pipeline", "queue_depth", d.queue_depth)?,
            seed: raw.typed("pipeline", "seed", d.seed)?,
            artifacts_dir: raw
                .get("paths", "artifacts")
                .unwrap_or(&d.artifacts_dir)
                .to_string(),
            results_dir: raw
                .get("paths", "results")
                .unwrap_or(&d.results_dir)
                .to_string(),
        })
    }

    pub fn validate(&self) -> Result<()> {
        if self.train_frac <= 0.0 || self.train_frac >= 1.0 {
            return Err(Error::Config("train_frac must be in (0,1)".into()));
        }
        if self.workers == 0 || self.chunk_size == 0 || self.queue_depth == 0 {
            return Err(Error::Config("workers/chunk_size/queue_depth must be > 0".into()));
        }
        if self.vocab as u64 >= self.dim {
            return Err(Error::Config("vocab must be < dim".into()));
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_sections_and_comments() {
        let raw = RawConfig::parse(
            "# top comment\n[data]\nn_docs = 100 # inline\nvocab = 500\n\n[pipeline]\nworkers = 2\n",
        )
        .unwrap();
        assert_eq!(raw.get("data", "n_docs"), Some("100"));
        assert_eq!(raw.get("pipeline", "workers"), Some("2"));
        assert_eq!(raw.get("nope", "x"), None);
    }

    #[test]
    fn typed_conversion_and_defaults() {
        let raw = RawConfig::parse("[data]\nn_docs = 123\n").unwrap();
        let cfg = Config::from_raw(&raw).unwrap();
        assert_eq!(cfg.n_docs, 123);
        assert_eq!(cfg.vocab, Config::default().vocab); // default preserved
    }

    #[test]
    fn overrides() {
        let mut raw = RawConfig::default();
        raw.set("data.n_docs", "77").unwrap();
        assert_eq!(Config::from_raw(&raw).unwrap().n_docs, 77);
        assert!(raw.set("missingdot", "x").is_err());
    }

    #[test]
    fn bad_values_error() {
        let raw = RawConfig::parse("[data]\nn_docs = notanumber\n").unwrap();
        assert!(Config::from_raw(&raw).is_err());
        assert!(RawConfig::parse("keyonly\n").is_err());
    }

    #[test]
    fn validation() {
        let mut cfg = Config::default();
        cfg.validate().unwrap();
        cfg.train_frac = 1.5;
        assert!(cfg.validate().is_err());
        cfg.train_frac = 0.5;
        cfg.workers = 0;
        assert!(cfg.validate().is_err());
    }
}
