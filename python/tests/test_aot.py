"""AOT path tests: every catalogue entry lowers to parseable HLO text and
the manifest describes it faithfully."""

import os
import tempfile

from compile import aot


def test_catalogue_lowers_and_manifest_is_consistent():
    cat = aot.artifact_catalogue()
    assert set(cat) >= {
        "minhash_k200",
        "minhash_k512",
        "vw_bins1024",
        "train_logistic_b8_k200",
        "train_sqhinge_b8_k200",
        "predict_b8_k200",
    }
    # lower one representative of each family (full lowering is exercised
    # by `make artifacts`; keep the test fast)
    for name in ["minhash_k200", "train_logistic_b8_k200", "predict_b8_k200"]:
        fn, specs, consts = cat[name]
        text = aot.to_hlo_text(fn.lower(*specs))
        assert text.startswith("HloModule"), name
        assert "ENTRY" in text
        # constants that must round-trip into the manifest
        assert all(isinstance(v, int) for v in consts.values())


def test_main_writes_files_and_is_idempotent(tmp_path=None):
    out = tempfile.mkdtemp(prefix="bbit_aot_test_")
    import sys

    argv = sys.argv
    try:
        sys.argv = ["aot", "--out-dir", out, "--only", "predict_b8_k200"]
        assert aot.main() == 0
        files = os.listdir(out)
        assert "manifest.txt" in files
        assert "predict_b8_k200.hlo.txt" in files
        manifest = open(os.path.join(out, "manifest.txt")).read()
        assert "artifact predict_b8_k200" in manifest
        assert "const dim 51200" in manifest
        assert manifest.strip().endswith("end")
        # second run with unchanged sources is a fingerprint no-op
        sys.argv = ["aot", "--out-dir", out]
        assert aot.main() == 0
    finally:
        sys.argv = argv


def test_hlo_text_has_expected_entry_shapes():
    cat = aot.artifact_catalogue()
    fn, specs, _ = cat["minhash_k200"]
    text = aot.to_hlo_text(fn.lower(*specs))
    assert "s32[256,2048]" in text  # idx/mask inputs
    assert "u32[200]" in text  # hash parameters
    assert "s32[256,200]" in text  # output
