"""Pallas margins kernel + sgd step vs oracles (shape/dtype sweep)."""

import numpy as np
from hypothesis import given, settings, strategies as st

import jax.numpy as jnp

from compile import model
from compile.kernels.linear import BLOCK_B, bbit_margins
from compile.kernels.ref import margins_ref, sgd_step_ref

RNG = np.random.default_rng(0x11EA)


@settings(max_examples=25, deadline=None)
@given(
    blocks=st.integers(1, 4),
    k=st.integers(1, 64),
    b=st.sampled_from([1, 2, 4, 8, 12]),
    seed=st.integers(0, 2**32 - 1),
)
def test_margins_match_ref(blocks, k, b, seed):
    rng = np.random.default_rng(seed)
    n = blocks * BLOCK_B
    dim = (1 << b) * k
    w = jnp.asarray(rng.normal(size=dim).astype(np.float32))
    codes = jnp.asarray(rng.integers(0, 1 << b, size=(n, k), dtype=np.int32))
    got = np.asarray(bbit_margins(w, codes, b=b))
    want = np.asarray(margins_ref(w, codes, b=b))
    np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-5)


@settings(max_examples=15, deadline=None)
@given(
    k=st.integers(1, 32),
    b=st.sampled_from([1, 2, 4, 8]),
    loss=st.sampled_from(["logistic", "sqhinge"]),
    seed=st.integers(0, 2**32 - 1),
)
def test_sgd_step_matches_ref(k, b, loss, seed):
    rng = np.random.default_rng(seed)
    n = BLOCK_B
    dim = (1 << b) * k
    w = jnp.asarray(rng.normal(size=dim).astype(np.float32) * 0.1)
    codes = jnp.asarray(rng.integers(0, 1 << b, size=(n, k), dtype=np.int32))
    y = jnp.asarray(rng.choice([-1.0, 1.0], size=n).astype(np.float32))
    lr, lam = 0.1, 0.01
    got = np.asarray(model.sgd_step(w, codes, y, lr, lam, b=b, loss=loss))
    want = np.asarray(sgd_step_ref(w, codes, y, lr, lam, b=b, loss=loss))
    np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-5)


def test_train_chunk_decreases_loss():
    """A few SGD chunks on linearly-separable codes must reduce the loss
    and reach high training accuracy — the end-to-end L2 signal."""
    k, b, batch = 16, 4, BLOCK_B
    n = 4 * BLOCK_B
    dim = (1 << b) * k
    rng = np.random.default_rng(7)
    # Construct separable data: label decides which half of each 2^b range
    # the codes concentrate in.
    y = rng.choice([-1.0, 1.0], size=n).astype(np.float32)
    half = 1 << (b - 1)
    codes = np.where(
        (y[:, None] > 0),
        rng.integers(0, half, size=(n, k)),
        rng.integers(half, 1 << b, size=(n, k)),
    ).astype(np.int32)
    w = jnp.zeros(dim, dtype=jnp.float32)
    fn = model.jit_train_chunk(b, "logistic", batch)
    step = jnp.asarray(0, dtype=jnp.int32)
    for _ in range(6):
        w, step = fn(w, jnp.asarray(codes), jnp.asarray(y), 0.5, 1e-4, step)
    m = np.asarray(model.predict_margins(w, jnp.asarray(codes), b=b))
    acc = float(np.mean(np.sign(m) == y))
    assert acc > 0.95, acc
    assert int(step) == 6 * (n // batch)


def test_pad_batch_shapes():
    idx, mask = model.pad_batch([[1, 2], [3]], max_nnz=5, batch=8)
    assert idx.shape == (8, 128) and mask.shape == (8, 128)
    assert int(mask.sum()) == 3
