"""Pallas VW kernel vs oracle + the estimator properties of Section 5."""

import numpy as np
from hypothesis import given, settings, strategies as st

import jax.numpy as jnp

from compile.kernels.ref import PRIME, vw_hash_ref
from compile.kernels.vw import BLOCK_B, NNZ_CHUNK, vw_hash

RNG = np.random.default_rng(0x5757)


def padded_batch(rows, nnz):
    bsz = ((len(rows) + BLOCK_B - 1) // BLOCK_B) * BLOCK_B
    idx = np.zeros((bsz, nnz), dtype=np.int32)
    mask = np.zeros((bsz, nnz), dtype=np.int32)
    for i, r in enumerate(rows):
        idx[i, : len(r)] = r
        mask[i, : len(r)] = 1
    return jnp.asarray(idx), jnp.asarray(mask)


def draw_params(rng):
    a1 = int(rng.integers(0, PRIME))
    a2 = int(rng.integers(1, PRIME))
    s1 = int(rng.integers(0, PRIME))
    s2 = int(rng.integers(1, PRIME))
    return a1, a2, s1, s2


@settings(max_examples=25, deadline=None)
@given(
    n_rows=st.integers(1, 10),
    nnz_chunks=st.integers(1, 3),
    bins_log2=st.integers(1, 9),
    d_log2=st.integers(10, 30),
    seed=st.integers(0, 2**32 - 1),
)
def test_kernel_matches_ref(n_rows, nnz_chunks, bins_log2, d_log2, seed):
    rng = np.random.default_rng(seed)
    nnz = nnz_chunks * NNZ_CHUNK
    bins = 1 << bins_log2
    d_space = 1 << d_log2
    rows = [
        np.unique(rng.integers(0, d_space, size=rng.integers(1, nnz + 1)))
        for _ in range(n_rows)
    ]
    idx, mask = padded_batch(rows, nnz)
    a1, a2, s1, s2 = draw_params(rng)
    params = jnp.asarray([a1, a2, s1, s2], dtype=jnp.uint32)
    got = np.asarray(vw_hash(idx, mask, params, num_bins=bins))
    want = np.asarray(vw_hash_ref(idx, mask, a1, a2, s1, s2, num_bins=bins))
    np.testing.assert_allclose(got, want, rtol=0, atol=0)


def test_l1_mass_preserved():
    """Each nonzero lands in exactly one bin with weight +-1, so the sum of
    |bin| counts... cannot exceed nnz; and sum of bins^2 == nnz when there
    are no within-bin collisions cancelling."""
    nnz = NNZ_CHUNK
    rows = [RNG.choice(1 << 20, size=57, replace=False)]
    idx, mask = padded_batch(rows, nnz)
    params = jnp.asarray(draw_params(RNG), dtype=jnp.uint32)
    g = np.asarray(vw_hash(idx, mask, params, num_bins=4096))[0]
    # with 4096 bins and 57 items collisions are rare but possible; the sum
    # of absolute bin masses changes parity only through cancellation:
    assert np.sum(np.abs(g)) <= 57
    assert np.sum(np.abs(g)) % 2 == 57 % 2  # cancellation removes pairs


def test_inner_product_unbiased():
    """E[g1 . g2] = u1 . u2 = |S1 ^ S2| for binary data (paper Eq. 15),
    checked by averaging over many parameter draws."""
    d_space = 1 << 22
    shared = RNG.choice(d_space, size=60, replace=False)
    only1 = RNG.choice(d_space, size=40, replace=False)
    only2 = RNG.choice(d_space, size=40, replace=False)
    s1v = np.unique(np.concatenate([shared, only1]))
    s2v = np.unique(np.concatenate([shared, only2]))
    a_true = len(np.intersect1d(s1v, s2v))
    idx, mask = padded_batch([s1v, s2v], NNZ_CHUNK)
    bins = 256
    trials = 150
    ests = []
    for _ in range(trials):
        params = jnp.asarray(draw_params(RNG), dtype=jnp.uint32)
        g = np.asarray(vw_hash(idx, mask, params, num_bins=bins))
        ests.append(float(g[0] @ g[1]))
    est = np.mean(ests)
    # Var ~= (f1*f2 + a^2 - 2*sum u1^2u2^2)/k per Eq. 16; loose 5-sigma gate
    var = (len(s1v) * len(s2v) + a_true**2) / bins
    tol = 5 * np.sqrt(var / trials)
    assert abs(est - a_true) < tol, (est, a_true, tol)
