"""L2 model tests: shapes, scan semantics, and end-to-end learning through
the exact functions aot.py lowers."""

import numpy as np
import jax.numpy as jnp
import pytest

from compile import model
from compile.kernels.ref import PRIME, minhash_ref, sgd_step_ref


def test_preprocess_minhash_shapes_and_ref():
    rng = np.random.default_rng(1)
    d = 1 << 28
    idx = jnp.asarray(rng.integers(0, d, size=(8, 128), dtype=np.int32))
    mask = jnp.ones((8, 128), dtype=jnp.int32)
    c1 = jnp.asarray(rng.integers(0, PRIME, size=16, dtype=np.uint64).astype(np.uint32))
    c2 = jnp.asarray(rng.integers(1, PRIME, size=16, dtype=np.uint64).astype(np.uint32))
    out = model.preprocess_minhash(idx, mask, c1, c2, d_space=d)
    assert out.shape == (8, 16) and out.dtype == jnp.int32
    np.testing.assert_array_equal(
        np.asarray(out), np.asarray(minhash_ref(idx, mask, c1, c2, d_space=d))
    )


def test_preprocess_vw_shapes():
    rng = np.random.default_rng(2)
    idx = jnp.asarray(rng.integers(0, 1 << 20, size=(8, 128), dtype=np.int32))
    mask = jnp.ones((8, 128), dtype=jnp.int32)
    params = jnp.asarray([3, 5, 7, 11], dtype=jnp.uint32)
    out = model.preprocess_vw(idx, mask, params, num_bins=64)
    assert out.shape == (8, 64) and out.dtype == jnp.float32
    # mass conservation: each of the 8*128 items lands once with sign +-1
    assert float(jnp.abs(out).sum()) <= 8 * 128


@pytest.mark.parametrize("loss", ["logistic", "sqhinge"])
def test_train_chunk_equals_manual_step_loop(loss):
    """The scanned chunk must equal applying sgd_step_ref minibatch by
    minibatch with the decayed schedule."""
    rng = np.random.default_rng(3)
    b, k, batch, n = 4, 8, 128, 256
    dim = (1 << b) * k
    codes = rng.integers(0, 1 << b, size=(n, k), dtype=np.int32)
    y = rng.choice([-1.0, 1.0], size=n).astype(np.float32)
    w0 = rng.normal(size=dim).astype(np.float32) * 0.01
    lr0, lam = 0.3, 1e-3
    fn = model.jit_train_chunk(b, loss, batch)
    # jit_train_chunk donates its weight buffer; keep the numpy original
    w_got, steps = fn(
        jnp.asarray(w0), jnp.asarray(codes), jnp.asarray(y), lr0, lam,
        jnp.asarray(2, jnp.int32),
    )
    assert int(steps) == 2 + n // batch

    w_want = jnp.asarray(w0)
    step = 2
    for i0 in range(0, n, batch):
        lr = lr0 / (1.0 + step * lam * lr0)
        w_want = sgd_step_ref(
            w_want,
            jnp.asarray(codes[i0 : i0 + batch]),
            jnp.asarray(y[i0 : i0 + batch]),
            lr,
            lam,
            b=b,
            loss=loss,
        )
        step += 1
    np.testing.assert_allclose(np.asarray(w_got), np.asarray(w_want), rtol=2e-4, atol=1e-6)


def test_train_chunk_rejects_ragged():
    fn = model.jit_train_chunk(2, "logistic", 128)
    w = jnp.zeros(4 * 8, jnp.float32)
    with pytest.raises(ValueError):
        fn(w, jnp.zeros((129, 8), jnp.int32), jnp.zeros(129, jnp.float32), 0.1, 0.1,
           jnp.asarray(0, jnp.int32))


def test_predict_sign_flip_symmetry():
    rng = np.random.default_rng(4)
    b, k = 4, 8
    dim = (1 << b) * k
    w = jnp.asarray(rng.normal(size=dim).astype(np.float32))
    codes = jnp.asarray(rng.integers(0, 1 << b, size=(128, k), dtype=np.int32))
    m = model.predict_margins(w, codes, b=b)
    m_neg = model.predict_margins(-w, codes, b=b)
    np.testing.assert_allclose(np.asarray(m), -np.asarray(m_neg), rtol=1e-5, atol=1e-6)
