"""Pallas minhash kernel vs pure-jnp oracle, plus statistical properties.

The hypothesis sweep drives the kernel over random batch sizes, nonzero
counts, k, index distributions and hash-parameter draws — shape/dtype
coverage as required for the L1 kernel.  The statistical tests check the
*estimator* properties the paper builds on: collision probability == R
(Section 2) within Monte-Carlo error.
"""

import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

import jax.numpy as jnp

from compile.kernels.minhash import BLOCK_B, NNZ_CHUNK, minhash
from compile.kernels.ref import PRIME, bbit_codes_ref, minhash_ref

RNG = np.random.default_rng(0xB817)


def draw_params(k, rng):
    c1 = rng.integers(0, PRIME, size=k, dtype=np.uint64).astype(np.uint32)
    c2 = rng.integers(1, PRIME, size=k, dtype=np.uint64).astype(np.uint32)
    return jnp.asarray(c1), jnp.asarray(c2)


def padded_batch(rows, nnz):
    bsz = ((len(rows) + BLOCK_B - 1) // BLOCK_B) * BLOCK_B
    idx = np.zeros((bsz, nnz), dtype=np.int32)
    mask = np.zeros((bsz, nnz), dtype=np.int32)
    for i, r in enumerate(rows):
        idx[i, : len(r)] = r
        mask[i, : len(r)] = 1
    return jnp.asarray(idx), jnp.asarray(mask)


@settings(max_examples=25, deadline=None)
@given(
    n_rows=st.integers(1, 12),
    nnz_chunks=st.integers(1, 3),
    k=st.integers(1, 64),
    d_log2=st.integers(8, 30),
    seed=st.integers(0, 2**32 - 1),
)
def test_kernel_matches_ref(n_rows, nnz_chunks, k, d_log2, seed):
    rng = np.random.default_rng(seed)
    nnz = nnz_chunks * NNZ_CHUNK
    d_space = 1 << d_log2
    rows = [
        np.unique(rng.integers(0, d_space, size=rng.integers(1, nnz + 1)))
        for _ in range(n_rows)
    ]
    idx, mask = padded_batch(rows, nnz)
    c1, c2 = draw_params(k, rng)
    got = minhash(idx, mask, c1, c2, d_space=d_space)
    want = minhash_ref(idx, mask, c1, c2, d_space=d_space)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


def test_empty_rows_get_sentinel():
    d_space = 1 << 20
    idx, mask = padded_batch([[], [1, 2, 3]], NNZ_CHUNK)
    c1, c2 = draw_params(4, RNG)
    z = np.asarray(minhash(idx, mask, c1, c2, d_space=d_space))
    assert (z[0] == d_space).all()
    assert (z[1] < d_space).all()


def test_order_and_padding_invariance():
    """Minwise value is a set function: permutation of the nonzeros and the
    amount of padding must not change the output."""
    d_space = 1 << 24
    base = RNG.choice(d_space, size=100, replace=False)
    c1, c2 = draw_params(16, RNG)
    a_idx, a_mask = padded_batch([base], NNZ_CHUNK)
    b_idx, b_mask = padded_batch([RNG.permutation(base)], 3 * NNZ_CHUNK)
    za = np.asarray(minhash(a_idx, a_mask, c1, c2, d_space=d_space))[0]
    zb = np.asarray(minhash(b_idx, b_mask, c1, c2, d_space=d_space))[0]
    np.testing.assert_array_equal(za, zb)


def test_collision_probability_estimates_resemblance():
    """Pr(min collision) == R (paper Eq. 1): the k-sample estimator must
    land within 5 sigma of R with sigma^2 = R(1-R)/k (Eq. 2)."""
    d_space = 1 << 26
    k = 2048
    shared = RNG.choice(d_space, size=300, replace=False)
    only1 = RNG.choice(d_space, size=150, replace=False)
    only2 = RNG.choice(d_space, size=150, replace=False)
    s1 = np.unique(np.concatenate([shared, only1]))
    s2 = np.unique(np.concatenate([shared, only2]))
    r_true = len(np.intersect1d(s1, s2)) / len(np.union1d(s1, s2))
    nnz = ((max(len(s1), len(s2)) + NNZ_CHUNK - 1) // NNZ_CHUNK) * NNZ_CHUNK
    idx, mask = padded_batch([s1, s2], nnz)
    c1, c2 = draw_params(k, RNG)
    z = np.asarray(minhash(idx, mask, c1, c2, d_space=d_space))
    r_hat = float(np.mean(z[0] == z[1]))
    sigma = np.sqrt(r_true * (1 - r_true) / k)
    assert abs(r_hat - r_true) < 5 * sigma, (r_hat, r_true, sigma)


@pytest.mark.parametrize("b", [1, 2, 4, 8, 12, 16])
def test_bbit_collision_probability(b):
    """P_b ~= 1/2^b + (1 - 1/2^b) R for sparse data (paper Eq. 5)."""
    d_space = 1 << 26
    k = 4096
    shared = RNG.choice(d_space, size=400, replace=False)
    only1 = RNG.choice(d_space, size=100, replace=False)
    only2 = RNG.choice(d_space, size=100, replace=False)
    s1 = np.unique(np.concatenate([shared, only1]))
    s2 = np.unique(np.concatenate([shared, only2]))
    r_true = len(np.intersect1d(s1, s2)) / len(np.union1d(s1, s2))
    nnz = ((max(len(s1), len(s2)) + NNZ_CHUNK - 1) // NNZ_CHUNK) * NNZ_CHUNK
    idx, mask = padded_batch([s1, s2], nnz)
    c1, c2 = draw_params(k, RNG)
    z = jnp.asarray(minhash(idx, mask, c1, c2, d_space=d_space))
    codes = np.asarray(bbit_codes_ref(z, b))
    p_hat = float(np.mean(codes[0] == codes[1]))
    p_theory = 1 / 2**b + (1 - 1 / 2**b) * r_true
    sigma = np.sqrt(p_theory * (1 - p_theory) / k)
    assert abs(p_hat - p_theory) < 5 * sigma + 0.01, (b, p_hat, p_theory)
