"""AOT lowering: jax entry points -> HLO *text* artifacts + manifest.

Interchange format is HLO text, NOT a serialized HloModuleProto: jax >= 0.5
emits protos with 64-bit instruction ids which the xla crate's
xla_extension 0.5.1 rejects (proto.id() <= INT_MAX); the text parser
reassigns ids, so text round-trips cleanly (see /opt/xla-example/README.md).

Usage:  cd python && python -m compile.aot --out-dir ../artifacts

Emits one ``<name>.hlo.txt`` per entry in ARTIFACTS plus ``manifest.txt``,
a line-oriented manifest the rust runtime parses (no JSON dependency):

    artifact <name>
    file <name>.hlo.txt
    const <key> <int>
    input <name> <dtype> <d0>x<d1>...
    output <dtype> <d0>x...
    end

All entry points are lowered with return_tuple=True; the rust side unwraps
with to_tuple1().
"""

from __future__ import annotations

import argparse
import hashlib
import os
import sys

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from . import model
from .kernels.ref import PRIME

# ---------------------------------------------------------------------------
# Artifact catalogue.  Shapes are chosen for the e2e driver and Table-2
# preprocessing bench; the rust coordinator chunks/pads its data to these.
# ---------------------------------------------------------------------------

# Shared shape constants (must match rust/src/runtime/artifacts.rs).
PRE_B = 256      # documents per preprocessing call
PRE_NNZ = 2048   # padded nonzeros per document (expanded docs reach ~1.9k)
PRE_NNZ_SMALL = 512   # small-document variant (coordinator routes by nnz)
PRE_NNZ_MID = 1024    # mid-size variant
MH_K = 200       # minwise hashes for the e2e config (b=8, k=200)
MH_K_T2 = 512    # minwise hashes for the Table-2 bench (paper uses k=500)
VW_BINS = 1024   # VW bins for the runtime artifact
D_SPACE = 1 << 30  # rehashed index space (paper: D ~ 2^30 via expansion)

TRAIN_B = 8      # bits for the e2e train artifact
TRAIN_K = MH_K
TRAIN_CHUNK = 2048  # rows per train_chunk call
TRAIN_BATCH = 256   # SGD minibatch
PRED_N = 2048       # rows per predict call


def _spec(shape, dtype):
    return jax.ShapeDtypeStruct(shape, dtype)


def artifact_catalogue():
    """name -> (jitted fn, example args, consts dict)."""
    u32, i32, f32 = jnp.uint32, jnp.int32, jnp.float32
    cat = {}

    cat["minhash_k200"] = (
        model.jit_preprocess_minhash(D_SPACE),
        (
            _spec((PRE_B, PRE_NNZ), i32),
            _spec((PRE_B, PRE_NNZ), i32),
            _spec((MH_K,), u32),
            _spec((MH_K,), u32),
        ),
        {"p": PRIME, "d_space": D_SPACE, "k": MH_K, "batch": PRE_B, "nnz": PRE_NNZ},
    )
    cat["minhash_k512"] = (
        model.jit_preprocess_minhash(D_SPACE),
        (
            _spec((PRE_B, PRE_NNZ), i32),
            _spec((PRE_B, PRE_NNZ), i32),
            _spec((MH_K_T2,), u32),
            _spec((MH_K_T2,), u32),
        ),
        {"p": PRIME, "d_space": D_SPACE, "k": MH_K_T2, "batch": PRE_B, "nnz": PRE_NNZ},
    )
    # Small-nnz variants: most documents have far fewer nonzeros than the
    # padded maximum, and padded work is wasted work — the rust coordinator
    # routes each document to the smallest variant it fits (§Perf: ~4x on
    # typical corpora).
    for name, k, nnz in (
        ("minhash_k200_nnz512", MH_K, PRE_NNZ_SMALL),
        ("minhash_k512_nnz512", MH_K_T2, PRE_NNZ_SMALL),
        ("minhash_k512_nnz1024", MH_K_T2, PRE_NNZ_MID),
    ):
        cat[name] = (
            model.jit_preprocess_minhash(D_SPACE),
            (
                _spec((PRE_B, nnz), i32),
                _spec((PRE_B, nnz), i32),
                _spec((k,), u32),
                _spec((k,), u32),
            ),
            {"p": PRIME, "d_space": D_SPACE, "k": k, "batch": PRE_B, "nnz": nnz},
        )
    cat["vw_bins1024"] = (
        model.jit_preprocess_vw(VW_BINS),
        (
            _spec((PRE_B, PRE_NNZ), i32),
            _spec((PRE_B, PRE_NNZ), i32),
            _spec((4,), u32),
        ),
        {"p": PRIME, "bins": VW_BINS, "batch": PRE_B, "nnz": PRE_NNZ},
    )

    dim = (1 << TRAIN_B) * TRAIN_K
    for loss in ("logistic", "sqhinge"):
        cat[f"train_{loss}_b8_k200"] = (
            model.jit_train_chunk(TRAIN_B, loss, TRAIN_BATCH),
            (
                _spec((dim,), f32),
                _spec((TRAIN_CHUNK, TRAIN_K), i32),
                _spec((TRAIN_CHUNK,), f32),
                _spec((), f32),  # lr0
                _spec((), f32),  # lam
                _spec((), i32),  # step0
            ),
            {
                "b": TRAIN_B,
                "k": TRAIN_K,
                "dim": dim,
                "chunk": TRAIN_CHUNK,
                "batch": TRAIN_BATCH,
            },
        )
    cat["predict_b8_k200"] = (
        model.jit_predict(TRAIN_B),
        (_spec((dim,), f32), _spec((PRED_N, TRAIN_K), i32)),
        {"b": TRAIN_B, "k": TRAIN_K, "dim": dim, "n": PRED_N},
    )
    return cat


def to_hlo_text(lowered) -> str:
    """stablehlo -> XlaComputation -> HLO text (the 0.5.1-safe path)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def _dtype_name(dt) -> str:
    return jnp.dtype(dt).name


def _inputs_fingerprint(paths) -> str:
    h = hashlib.sha256()
    for p in sorted(paths):
        with open(p, "rb") as f:
            h.update(f.read())
    return h.hexdigest()[:16]


def main() -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out-dir", default="../artifacts")
    ap.add_argument("--only", default=None, help="comma-list of artifact names")
    args = ap.parse_args()
    os.makedirs(args.out_dir, exist_ok=True)

    here = os.path.dirname(os.path.abspath(__file__))
    src_files = [
        os.path.join(dp, f)
        for dp, _, fs in os.walk(here)
        for f in fs
        if f.endswith(".py") and "__pycache__" not in dp
    ]
    fingerprint = _inputs_fingerprint(src_files)
    stamp = os.path.join(args.out_dir, "fingerprint.txt")
    if os.path.exists(stamp) and open(stamp).read().strip() == fingerprint:
        if args.only is None:
            print(f"artifacts up to date (fingerprint {fingerprint})")
            return 0

    cat = artifact_catalogue()
    only = set(args.only.split(",")) if args.only else None
    manifest_lines = []
    for name, (fn, specs, consts) in cat.items():
        if only and name not in only:
            continue
        lowered = fn.lower(*specs)
        text = to_hlo_text(lowered)
        fname = f"{name}.hlo.txt"
        with open(os.path.join(args.out_dir, fname), "w") as f:
            f.write(text)
        out_specs = jax.eval_shape(fn, *specs)
        leaves = jax.tree_util.tree_leaves(out_specs)
        manifest_lines.append(f"artifact {name}")
        manifest_lines.append(f"file {fname}")
        for key, val in consts.items():
            manifest_lines.append(f"const {key} {val}")
        for i, s in enumerate(specs):
            dims = "x".join(str(d) for d in s.shape) if s.shape else "scalar"
            manifest_lines.append(f"input arg{i} {_dtype_name(s.dtype)} {dims}")
        for leaf in leaves:
            dims = "x".join(str(d) for d in leaf.shape) if leaf.shape else "scalar"
            manifest_lines.append(f"output {_dtype_name(leaf.dtype)} {dims}")
        manifest_lines.append("end")
        print(f"lowered {name}: {len(text)} chars")

    with open(os.path.join(args.out_dir, "manifest.txt"), "w") as f:
        f.write("\n".join(manifest_lines) + "\n")
    with open(stamp, "w") as f:
        f.write(fingerprint + "\n")
    print(f"wrote manifest with {len(manifest_lines)} lines; fingerprint {fingerprint}")
    return 0


if __name__ == "__main__":
    sys.exit(main())
