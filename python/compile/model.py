"""L2: the jax compute graph AOT-lowered for the rust coordinator.

Entry points (each becomes one HLO-text artifact; shapes are fixed at
lowering time by aot.py and recorded in the manifest):

- preprocess_minhash: batched minwise hashing (wraps the L1 pallas kernel).
- preprocess_vw:      batched VW hashing (wraps the L1 pallas kernel).
- train_chunk_{logistic,sqhinge}: a lax.scan over minibatches of b-bit
  codes performing SGD steps on  lam/2 |w|^2 + mean loss  -- the whole
  chunk runs device-side with the weight buffer donated, so the rust hot
  loop does one PJRT execute per chunk, not per step.
- predict_margins:    margins for evaluation / accuracy.

Everything here is callable from python for tests, but at run time only
the lowered HLO is used (python is never on the request path).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from .kernels import bbit_margins, minhash, vw_hash
from .kernels.ref import (
    logistic_grad_coef_ref,
    sqhinge_grad_coef_ref,
)


def preprocess_minhash(idx, mask, c1, c2, *, d_space: int):
    """[B, NNZ] padded index sets -> [B, k] int32 minwise values."""
    return minhash(idx, mask, c1, c2, d_space=d_space)


def preprocess_vw(idx, mask, params, *, num_bins):
    """[B, NNZ] padded index sets -> [B, num_bins] float32 VW vectors.

    params: [4] uint32 = (a1, a2, s1, s2) hash parameters.
    """
    return vw_hash(idx, mask, params, num_bins=num_bins)


def _grad_coef(loss: str):
    if loss == "logistic":
        return logistic_grad_coef_ref
    if loss == "sqhinge":
        return sqhinge_grad_coef_ref
    raise ValueError(f"unknown loss {loss!r}")


def sgd_step(w, codes, y, lr, lam, *, b: int, loss: str):
    """One minibatch SGD step; pallas gather for margins, HLO scatter for
    the update. Mirrors kernels.ref.sgd_step_ref exactly."""
    k = codes.shape[1]
    m = bbit_margins(w, codes, b=b)
    g = _grad_coef(loss)(m, y)
    offsets = jnp.arange(k, dtype=jnp.int32) * (1 << b)
    cols = (codes + offsets[None, :]).reshape(-1)
    bsz = codes.shape[0]
    w = w * (1.0 - lr * lam)
    upd = jnp.zeros_like(w).at[cols].add(jnp.repeat(g, k) / bsz)
    return w - lr * upd


def train_chunk(w, codes, y, lr0, lam, step0, *, b: int, loss: str, batch: int):
    """Scan SGD over a [N, k] chunk split into N/batch minibatches.

    lr decays as lr0 / (1 + step * lam * lr0)  (Bottou's schedule); step0
    carries the global step count across chunks so the schedule is
    continuous over the epoch. Returns (w', steps_done).
    """
    n, k = codes.shape
    if n % batch != 0:
        raise ValueError(f"chunk rows {n} must be a multiple of batch {batch}")
    n_steps = n // batch
    codes_r = codes.reshape(n_steps, batch, k)
    y_r = y.reshape(n_steps, batch)

    def body(carry, xs):
        w, step = carry
        cb, yb = xs
        lr = lr0 / (1.0 + step.astype(jnp.float32) * lam * lr0)
        w = sgd_step(w, cb, yb, lr, lam, b=b, loss=loss)
        return (w, step + 1), ()

    (w, step), _ = jax.lax.scan(body, (w, step0), (codes_r, y_r))
    return w, step


def predict_margins(w, codes, *, b: int):
    """[N, k] codes -> [N] float32 margins (sign = predicted label)."""
    return bbit_margins(w, codes, b=b)


# ---------------------------------------------------------------------------
# jit wrappers with static configuration, used by aot.py for lowering and by
# the python test-suite directly.
# ---------------------------------------------------------------------------


def jit_preprocess_minhash(d_space: int):
    return jax.jit(functools.partial(preprocess_minhash, d_space=d_space))


def jit_preprocess_vw(num_bins: int):
    return jax.jit(functools.partial(preprocess_vw, num_bins=num_bins))


def jit_train_chunk(b: int, loss: str, batch: int):
    return jax.jit(
        functools.partial(train_chunk, b=b, loss=loss, batch=batch),
        donate_argnums=(0,),
    )


def jit_predict(b: int):
    return jax.jit(functools.partial(predict_margins, b=b))


def pad_batch(rows, max_nnz: int, batch: int, pad_multiple_nnz: int = 128):
    """Pack a list of python index lists into padded idx/mask arrays.

    Test/debug helper mirroring what the rust coordinator does natively.
    """
    import numpy as np

    nnz = max(max_nnz, pad_multiple_nnz)
    nnz = ((nnz + pad_multiple_nnz - 1) // pad_multiple_nnz) * pad_multiple_nnz
    bsz = ((len(rows) + batch - 1) // batch) * batch
    idx = np.zeros((bsz, nnz), dtype=np.int32)
    mask = np.zeros((bsz, nnz), dtype=np.int32)
    for i, row in enumerate(rows):
        row = row[:nnz]
        idx[i, : len(row)] = row
        mask[i, : len(row)] = 1
    return jnp.asarray(idx), jnp.asarray(mask)
