"""L1 Pallas kernel: VW feature hashing (signed Count-Min, paper Eq. 14).

The comparison baseline: every nonzero index t is hashed to a bin
bin(t) = ((a1 + a2 t) mod p) mod k and accumulated with a +/-1 sign drawn
from a second 2-universal hash (the bias-correcting r_t of Weinberger et
al., s = 1).  For binary data the hashed vector is
g_j = sum_{t in S} sign(t) * 1{bin(t) = j}.

TPU mapping: grid over document tiles; the inner loop sweeps nonzero slabs
and accumulates a [BLOCK_B, k] register tile via a one-hot compare against
a lane iota -- the Pallas analogue of the CUDA scatter-into-shared-memory
the original implementation uses.  Scatter-free, so it vectorizes on the
VPU without atomics.

The four hash parameters (a1, a2, s1, s2) arrive as a [4] uint32 runtime
input so one AOT artifact serves every seed the coordinator draws.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from .ref import PRIME

BLOCK_B = 8
NNZ_CHUNK = 128


def _vw_kernel(idx_ref, mask_ref, params_ref, out_ref, *, num_bins, p):
    nnz = idx_ref.shape[1]
    params = params_ref[...].astype(jnp.uint64)  # [4] = a1, a2, s1, s2
    a1, a2, s1, s2 = params[0], params[1], params[2], params[3]
    bins_iota = jnp.arange(num_bins, dtype=jnp.uint64)[None, None, :]

    def body(chunk, acc):
        start = chunk * NNZ_CHUNK
        t = jax.lax.dynamic_slice(
            idx_ref[...], (0, start), (idx_ref.shape[0], NNZ_CHUNK)
        ).astype(jnp.uint64)
        msk = jax.lax.dynamic_slice(
            mask_ref[...], (0, start), (mask_ref.shape[0], NNZ_CHUNK)
        )
        hb = ((a1 + a2 * t) % jnp.uint64(p)) % jnp.uint64(num_bins)
        hs = (s1 + s2 * t) % jnp.uint64(p)
        sign = jnp.where(hs % jnp.uint64(2) == 0, 1.0, -1.0) * (msk != 0)
        onehot = (hb[:, :, None] == bins_iota).astype(jnp.float32)
        return acc + jnp.sum(sign[:, :, None].astype(jnp.float32) * onehot, axis=1)

    n_chunks = nnz // NNZ_CHUNK
    init = jnp.zeros((idx_ref.shape[0], num_bins), dtype=jnp.float32)
    out_ref[...] = jax.lax.fori_loop(0, n_chunks, body, init)


@functools.partial(jax.jit, static_argnames=("num_bins",))
def vw_hash(idx, mask, params, *, num_bins: int):
    """VW-hash a padded batch of binary index sets to [B, num_bins] float32.

    params: [4] uint32 = (a1, a2, s1, s2); a1/a2 parameterize the bin
    hash, s1/s2 the sign hash, both 2-universal with prime PRIME.
    num_bins is the paper's k for VW.
    """
    bsz, nnz = idx.shape
    if nnz % NNZ_CHUNK != 0:
        raise ValueError(f"NNZ {nnz} must be a multiple of {NNZ_CHUNK}")
    if bsz % BLOCK_B != 0:
        raise ValueError(f"batch {bsz} must be a multiple of {BLOCK_B}")
    grid = (bsz // BLOCK_B,)
    return pl.pallas_call(
        functools.partial(_vw_kernel, num_bins=num_bins, p=PRIME),
        grid=grid,
        in_specs=[
            pl.BlockSpec((BLOCK_B, nnz), lambda i: (i, 0)),
            pl.BlockSpec((BLOCK_B, nnz), lambda i: (i, 0)),
            pl.BlockSpec((4,), lambda i: (0,)),
        ],
        out_specs=pl.BlockSpec((BLOCK_B, num_bins), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((bsz, num_bins), jnp.float32),
        interpret=True,
    )(idx, mask, params)
