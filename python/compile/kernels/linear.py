"""L1 Pallas kernel: margins of a linear model over b-bit expanded codes.

Section 3 of the paper expands each hashed data point into a 2^b * k
binary vector with exactly k ones at columns j*2^b + code_j.  The dot
product w . x_i therefore reduces to a k-way gather-sum; this kernel
computes a whole minibatch of margins with the weight vector staged once
into VMEM and re-used across the document tile (the dominant read is w,
which is why keeping it tile-resident matters -- see DESIGN.md Section 6).

The scatter half of the SGD step lives at L2 (model.py) as a jnp
``.at[].add`` so it lowers to a native HLO scatter; the gather/margin half
is the compute hot spot and lives here.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# Document-axis tile.  128 rows × k=200 codes (100 KB int32) + the
# 2^b·k weight vector (200 KB f32 at b=8, k=200) stay comfortably inside
# VMEM; fewer grid steps also cut interpret-mode dispatch overhead ~4×
# on the CPU path (§Perf).
BLOCK_B = 128


def _margins_kernel(w_ref, codes_ref, out_ref, *, b):
    codes = codes_ref[...]  # [BLOCK_B, k]
    k = codes.shape[1]
    offsets = jnp.arange(k, dtype=jnp.int32) * (1 << b)
    cols = codes + offsets[None, :]
    w = w_ref[...]  # [2^b * k] VMEM-resident for the tile
    out_ref[...] = jnp.sum(w[cols], axis=1)


@functools.partial(jax.jit, static_argnames=("b",))
def bbit_margins(w, codes, *, b: int):
    """Margins w.x for every row of a [N, k] int32 code matrix.

    w: [2^b * k] float32 weight vector; codes values must be < 2^b.
    Returns [N] float32.
    """
    n, k = codes.shape
    if n % BLOCK_B != 0:
        raise ValueError(f"batch {n} must be a multiple of {BLOCK_B}")
    dim = (1 << b) * k
    if w.shape != (dim,):
        raise ValueError(f"w must have shape ({dim},), got {w.shape}")
    grid = (n // BLOCK_B,)
    return pl.pallas_call(
        functools.partial(_margins_kernel, b=b),
        grid=grid,
        in_specs=[
            pl.BlockSpec((dim,), lambda i: (0,)),
            pl.BlockSpec((BLOCK_B, k), lambda i: (i, 0)),
        ],
        out_specs=pl.BlockSpec((BLOCK_B,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct((n,), jnp.float32),
        interpret=True,
    )(w, codes)
