"""L1 Pallas kernel: k-way 2-universal minwise hashing.

This is the paper's preprocessing hot spot (Section 6 / Table 2): for each
document (a set of feature indices) apply k independent 2-universal hashes
h_j(t) = ((c1_j + c2_j * t) mod p) mod D and keep the minimum over the
document's nonzeros.  The paper offloads this to a GPU; here it is a Pallas
kernel so the same computation AOT-lowers into the HLO artifact the rust
coordinator executes via PJRT.

TPU mapping (DESIGN.md "Hardware adaptation"): the grid tiles the document
axis; each grid step stages one [BLOCK_B, max_nnz] int32 index tile into
VMEM (BlockSpec), then sweeps the nonzero axis in NNZ_CHUNK-sized slabs,
updating a [BLOCK_B, k] running minimum that stays VMEM-resident for the
whole tile.  The inner [BLOCK_B, NNZ_CHUNK, k] hash lattice is pure VPU
integer work (mul/add/mod/min); nothing touches the MXU.  Under
interpret=True the same schedule runs as numpy loops, which is what the CPU
PJRT client executes.

Integer ranges: indices < 2^30 <= D, c2 < p = 2^31 - 1, so
c1 + c2 * t < 2^62 -- products stay inside uint64 with no overflow.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from .ref import PRIME

# Document-axis tile. 8 keeps the interpret-mode lattice small (16 measured
# 8% slower on CPU: the u64 lattice falls out of L2); on a real
# TPU the VMEM budget (Section 6 of DESIGN.md) admits 128.
BLOCK_B = 8
# Nonzero-axis slab swept by the inner loop.
NNZ_CHUNK = 128


def _minhash_kernel(idx_ref, mask_ref, c1_ref, c2_ref, out_ref, *, p, d_space):
    """One grid step: minwise-hash BLOCK_B documents against all k hashes."""
    c1 = c1_ref[...].astype(jnp.uint64)  # [k]
    c2 = c2_ref[...].astype(jnp.uint64)  # [k]
    nnz = idx_ref.shape[1]
    k = c1.shape[0]
    sentinel = jnp.uint64(d_space)

    def body(chunk, running_min):
        start = chunk * NNZ_CHUNK
        idx = jax.lax.dynamic_slice(
            idx_ref[...], (0, start), (idx_ref.shape[0], NNZ_CHUNK)
        ).astype(jnp.uint64)
        msk = jax.lax.dynamic_slice(
            mask_ref[...], (0, start), (mask_ref.shape[0], NNZ_CHUNK)
        )
        # [B, C, k] hash lattice; VPU integer ops only.
        h = (c1[None, None, :] + c2[None, None, :] * idx[:, :, None]) % jnp.uint64(p)
        h = h % jnp.uint64(d_space)
        h = jnp.where(msk[:, :, None] != 0, h, sentinel)
        return jnp.minimum(running_min, jnp.min(h, axis=1))

    n_chunks = nnz // NNZ_CHUNK
    init = jnp.full((idx_ref.shape[0], k), sentinel, dtype=jnp.uint64)
    result = jax.lax.fori_loop(0, n_chunks, body, init)
    out_ref[...] = result.astype(jnp.int32)


@functools.partial(jax.jit, static_argnames=("d_space",))
def minhash(idx, mask, c1, c2, *, d_space: int):
    """Minwise-hash a padded batch of index sets.

    idx:  [B, NNZ] int32  (NNZ must be a multiple of NNZ_CHUNK, B of BLOCK_B;
                           callers pad -- see model.pad_batch)
    mask: [B, NNZ] int32
    c1, c2: [k] uint32    2-universal parameters (c2 in [1, p))
    returns [B, k] int32 minwise values in [0, d_space]; d_space marks an
    empty set.
    """
    bsz, nnz = idx.shape
    if nnz % NNZ_CHUNK != 0:
        raise ValueError(f"NNZ {nnz} must be a multiple of {NNZ_CHUNK}")
    if bsz % BLOCK_B != 0:
        raise ValueError(f"batch {bsz} must be a multiple of {BLOCK_B}")
    k = c1.shape[0]
    grid = (bsz // BLOCK_B,)
    return pl.pallas_call(
        functools.partial(_minhash_kernel, p=PRIME, d_space=d_space),
        grid=grid,
        in_specs=[
            pl.BlockSpec((BLOCK_B, nnz), lambda i: (i, 0)),
            pl.BlockSpec((BLOCK_B, nnz), lambda i: (i, 0)),
            pl.BlockSpec((k,), lambda i: (0,)),
            pl.BlockSpec((k,), lambda i: (0,)),
        ],
        out_specs=pl.BlockSpec((BLOCK_B, k), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((bsz, k), jnp.int32),
        interpret=True,  # CPU PJRT cannot execute Mosaic custom-calls
    )(idx, mask, c1, c2)
