"""Pure-jnp reference oracles for the Pallas kernels.

Every kernel in this package has a reference implementation here written in
the most obvious possible jnp (no tiling, no loops, no tricks).  pytest +
hypothesis compare kernel output against these oracles over random shapes,
dtypes and parameter draws; the rust test-suite additionally cross-checks
its native implementations against the AOT'd artifacts, closing the loop
rust <-> HLO <-> pallas <-> ref.

Conventions shared by all layers (documented once, here):

- A data point is a *set* of feature indices ("nonzeros") in
  Omega = {0, .., D-1}; batches are padded to a fixed max-nnz with
  ``mask == 0`` marking padding slots.
- 2-universal hash family (paper Eq. 17):
      h_j(t) = ((c1_j + c2_j * t) mod p) mod D
  with prime p > D.  We fix p = 2^31 - 1 (Mersenne) inside the kernels:
  indices there are < 2^30 and c2 < p, so c1 + c2*t < 2^62 keeps all
  products within uint64.
- Minwise value of a set under h_j is min over nonzeros of h_j(t); the
  b-bit code keeps the lowest b bits (paper Section 2).
- The expanded feature vector of a code row is 2^b * k dimensional with
  exactly k ones at positions j * 2^b + code_j (paper Section 3) -- all
  linear algebra below uses the equivalent gather form.
"""

from __future__ import annotations

import jax.numpy as jnp

# Prime used inside kernels/refs (see module docstring).
PRIME = (1 << 31) - 1


def minhash_ref(idx, mask, c1, c2, *, d_space: int):
    """Minwise hashing oracle.

    idx:  [B, NNZ] int32   feature indices (padded)
    mask: [B, NNZ] int32   1 = real nonzero, 0 = padding
    c1:   [k]      uint32  2-universal offsets,  uniform in [0, p)
    c2:   [k]      uint32  2-universal slopes,   uniform in [1, p)
    returns z: [B, k] int32, z[i, j] = min_{t in S_i} h_j(t), or d_space
    for an empty set (sentinel, matches the kernel).
    """
    idx = idx.astype(jnp.uint64)[:, :, None]  # [B, NNZ, 1]
    c1 = c1.astype(jnp.uint64)[None, None, :]  # [1, 1, k]
    c2 = c2.astype(jnp.uint64)[None, None, :]
    h = ((c1 + c2 * idx) % jnp.uint64(PRIME)) % jnp.uint64(d_space)
    h = jnp.where(mask[:, :, None] != 0, h, jnp.uint64(d_space))
    return jnp.min(h, axis=1).astype(jnp.int32)


def bbit_codes_ref(z, b: int):
    """Lowest-b-bit truncation of minwise values (paper Section 2)."""
    return jnp.bitwise_and(z, (1 << b) - 1)


def vw_hash_ref(idx, mask, a1, a2, s1, s2, *, num_bins: int):
    """VW / feature-hashing oracle (paper Eq. 14, binary data u_t in {0,1}).

    bin(t)  = ((a1 + a2*t) mod p) mod num_bins
    sign(t) = +1 if ((s1 + s2*t) mod p) is even else -1   (the r_t, s = 1)
    out[i, j] = sum_{t in S_i} sign(t) * 1{bin(t) == j}
    """
    t = idx.astype(jnp.uint64)
    hb = ((jnp.uint64(a1) + jnp.uint64(a2) * t) % jnp.uint64(PRIME)) % jnp.uint64(
        num_bins
    )
    hs = (jnp.uint64(s1) + jnp.uint64(s2) * t) % jnp.uint64(PRIME)
    sign = jnp.where(hs % jnp.uint64(2) == 0, 1.0, -1.0) * (mask != 0)
    onehot = hb[:, :, None] == jnp.arange(num_bins, dtype=jnp.uint64)[None, None, :]
    return jnp.sum(sign[:, :, None] * onehot, axis=1).astype(jnp.float32)


def expand_cols_ref(codes, b: int):
    """Column indices of the k ones in the 2^b*k expansion (Section 3)."""
    k = codes.shape[-1]
    offsets = jnp.arange(k, dtype=jnp.int32) * (1 << b)
    return codes.astype(jnp.int32) + offsets


def margins_ref(w, codes, b: int):
    """w . x_i for the expanded representation == gather-sum."""
    cols = expand_cols_ref(codes, b)
    return jnp.sum(w[cols], axis=-1)


def logistic_grad_coef_ref(margins, y):
    """d loss / d margin for logistic loss log(1 + exp(-y m))."""
    return -y / (1.0 + jnp.exp(y * margins))


def sqhinge_grad_coef_ref(margins, y):
    """d loss / d margin for squared hinge max(1 - y m, 0)^2."""
    viol = jnp.maximum(1.0 - y * margins, 0.0)
    return -2.0 * y * viol


def sgd_step_ref(w, codes, y, lr, lam, *, b: int, loss: str):
    """One minibatch SGD step on  lam/2 |w|^2 + mean_i loss_i.

    Returns the updated weight vector.  This is the oracle for the fused
    train-step path (pallas gather kernel + jnp scatter in model.py).
    """
    cols = expand_cols_ref(codes, b)
    m = jnp.sum(w[cols], axis=-1)
    if loss == "logistic":
        g = logistic_grad_coef_ref(m, y)
    elif loss == "sqhinge":
        g = sqhinge_grad_coef_ref(m, y)
    else:
        raise ValueError(f"unknown loss {loss!r}")
    bsz = codes.shape[0]
    w = w * (1.0 - lr * lam)
    upd = (
        jnp.zeros_like(w)
        .at[cols.reshape(-1)]
        .add(jnp.repeat(g, codes.shape[1]) / bsz)
    )
    return w - lr * upd
