"""L1: Pallas kernels for the paper's compute hot-spots.

- minhash:  k-way 2-universal minwise hashing (preprocessing, Table 2)
- vw:       VW signed Count-Min feature hashing (baseline, Eq. 14)
- linear:   gather-sum margins over b-bit expanded codes (Section 3)
- ref:      pure-jnp oracles for all of the above
"""

from .linear import bbit_margins
from .minhash import minhash
from .vw import vw_hash

__all__ = ["bbit_margins", "minhash", "vw_hash"]
