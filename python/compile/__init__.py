"""Build-time compile package: L1 pallas kernels + L2 jax model + AOT.

Importing this package enables 64-bit jax types: the 2-universal hash
arithmetic ((c1 + c2*t) mod p with p = 2^31 - 1) requires uint64
intermediates; without x64 jnp silently downgrades them to uint32 and the
hashes collide with the rust implementation's.
"""

import jax

jax.config.update("jax_enable_x64", True)
