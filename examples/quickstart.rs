//! Quickstart: hash a small corpus with b-bit minwise hashing and train a
//! linear SVM on the hashed representation — the paper's whole workflow in
//! ~50 lines of library calls.
//!
//! Run: `cargo run --release --example quickstart`

use bbit_mh::coordinator::pipeline::{dataset_chunks, Pipeline, PipelineConfig};
use bbit_mh::data::gen::{CorpusConfig, CorpusGenerator};
use bbit_mh::encode::EncoderSpec;
use bbit_mh::solver::{accuracy, train_svm, SvmConfig};
use bbit_mh::util::Rng;

fn main() -> bbit_mh::Result<()> {
    // 1. A binary, sparse, high-dimensional dataset (here: generated; in
    //    production: streamed from LibSVM files — see e2e_rcv1_pipeline).
    let corpus = CorpusGenerator::new(CorpusConfig::rcv1_like(2000, 42)).generate();
    let (train_raw, test_raw) = corpus.split(0.5, &mut Rng::new(7));
    println!(
        "corpus: {} docs, D = {}, mean nnz = {:.0}",
        corpus.len(),
        corpus.dim,
        corpus.stats().nnz_mean
    );

    // 2. Preprocess through the streaming pipeline: k = 200 minwise hashes
    //    per document, keep the lowest b = 8 bits of each, pack.
    let (b, k) = (8, 200);
    let job = EncoderSpec::Bbit { b, k, d: corpus.dim, seed: 1 };
    let pipe = Pipeline::new(PipelineConfig::default());
    let (train_hashed, report) = pipe.run(dataset_chunks(&train_raw, 256), &job)?;
    let (test_hashed, _) = pipe.run(dataset_chunks(&test_raw, 256), &job)?;
    let train_hashed = train_hashed.into_bbit()?;
    let test_hashed = test_hashed.into_bbit()?;
    println!(
        "hashed {} docs in {:.3}s wall; packed size {} bytes (vs ~{} KB raw)",
        report.docs,
        report.wall_seconds,
        train_hashed.codes.ideal_bytes(),
        train_raw.approx_libsvm_bytes() / 1024,
    );

    // 3. Train linear SVM on the implicit 2^b × k expansion (Section 3) —
    //    no feature vectors are ever materialized.
    let (model, stats) = train_svm(&train_hashed, &SvmConfig::with_c(1.0));
    println!(
        "SVM (C=1) trained in {:.3}s, {} iterations",
        stats.train_seconds, stats.iterations
    );

    // 4. Evaluate.
    println!(
        "train accuracy {:.2}%, test accuracy {:.2}%",
        100.0 * accuracy(&model, &train_hashed),
        100.0 * accuracy(&model, &test_hashed),
    );
    Ok(())
}
