//! Re-using the hashed dataset beyond learning (paper Section 6): the same
//! packed b-bit signatures that feed the solvers drive near-duplicate
//! detection through the online similarity subsystem — no second pass over
//! the raw data, and the index that answers `POST /similar` in `bbit-mh
//! serve` is the one built here.
//!
//! Run: `cargo run --release --example near_duplicates`

use bbit_mh::coordinator::pipeline::{dataset_chunks, Pipeline, PipelineConfig};
use bbit_mh::data::dataset::{Example, SparseDataset};
use bbit_mh::data::gen::{CorpusConfig, CorpusGenerator};
use bbit_mh::encode::EncoderSpec;
use bbit_mh::hashing::lsh::LshConfig;
use bbit_mh::similarity::LshIndex;
use bbit_mh::util::Rng;

fn main() -> bbit_mh::Result<()> {
    // corpus with planted near-duplicates: every 10th document is a
    // lightly-perturbed copy of its predecessor
    let base = CorpusGenerator::new(CorpusConfig {
        n_docs: 1000,
        vocab: 1 << 20,
        zipf_alpha: 1.02,
        mean_tokens: 300.0,
        class_signal: 0.5,
        pos_fraction: 0.5,
        seed: 0xD0C5,
    })
    .generate();
    let mut rng = Rng::new(42);
    let mut ds = SparseDataset::new(base.dim);
    let mut planted = Vec::new();
    for i in 0..base.len() {
        let (idx, _) = base.row(i);
        ds.push(&Example::binary(base.labels[i], idx.to_vec()));
        if i % 10 == 9 {
            // perturb ~4% of tokens → resemblance ≈ 0.92
            let mut copy: Vec<u32> = idx.to_vec();
            for _ in 0..copy.len() / 25 {
                let pos = rng.below_usize(copy.len());
                copy[pos] = rng.below(base.dim) as u32;
            }
            planted.push((ds.len() as u64 - 1, ds.len() as u64));
            ds.push(&Example::binary(base.labels[i], copy));
        }
    }
    println!("corpus: {} docs, {} planted near-duplicate pairs", ds.len(), planted.len());

    // one hashing pass (the same codes a classifier would train on)
    let spec = EncoderSpec::Bbit { b: 8, k: 64, d: ds.dim, seed: 7 };
    let pipe = Pipeline::new(PipelineConfig::default());
    let (hashed, report) = pipe.run(dataset_chunks(&ds, 256), &spec)?;
    let hashed = hashed.into_bbit()?;
    println!(
        "hashed in {:.3}s → {} KB of signatures",
        report.wall_seconds,
        hashed.codes.ideal_bytes() / 1024
    );

    // the serving-grade index: 16 bands × 4 rows → threshold ≈ 0.5
    let cfg = LshConfig { bands: 16, rows_per_band: 4 };
    println!(
        "LSH bands=16 rows=4: S-curve threshold R ≈ {:.2}, P(cand | R=0.9) = {:.3}",
        cfg.threshold(),
        cfg.candidate_probability(0.9)
    );
    let index = LshIndex::from_codes(&hashed.codes, spec, cfg, 1)?;
    let pairs = index.near_duplicate_pairs(0.55);
    let found = planted
        .iter()
        .filter(|&&(a, b)| pairs.iter().any(|&(x, y, _)| (x, y) == (a, b)))
        .count();
    println!(
        "found {} candidate pairs; recall on planted duplicates: {}/{} ({:.0}%), {} non-planted",
        pairs.len(),
        found,
        planted.len(),
        100.0 * found as f64 / planted.len() as f64,
        pairs.len() - found,
    );
    assert!(found * 10 >= planted.len() * 9, "recall below 90%");

    // the same index answers point queries — this is what `POST /similar`
    // runs per request behind the batcher
    let (probe, partner) = planted[0];
    let (hits, stats) = index.query_doc(probe, 5)?;
    println!(
        "query doc {probe}: {} candidates → {} reranked, top hit {} (agreement {:.3})",
        stats.candidates, stats.reranked, hits[0].id, hits[0].estimate
    );
    assert_eq!(hits[0].id, probe, "a doc is its own nearest neighbor");
    assert!(
        hits.iter().any(|h| h.id == partner),
        "planted partner missing from top-5"
    );
    Ok(())
}
