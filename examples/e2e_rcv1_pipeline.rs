//! End-to-end driver (the DESIGN.md §4 mandated run): exercises every
//! layer of the stack on a real small workload and reports the paper's
//! headline metrics.
//!
//!   1. generate an rcv1-like corpus and expand features (the paper's own
//!      200 GB construction, scaled) — written to an actual LibSVM file;
//!   2. stream it back through the preprocessing pipeline (reader →
//!      sharded hash workers → packed b-bit store), b = 8, k = 200;
//!   3. train logistic regression **through the PJRT artifact** (L1 pallas
//!      gather kernel → L2 jax scan → HLO → rust runtime), logging the
//!      loss/accuracy curve per epoch;
//!   4. train the LIBLINEAR-style native solvers (DCD-SVM + Newton-LR)
//!      across the paper's C grid on the same hashed data;
//!   5. report test accuracies + every stage's wall-clock — the rows
//!      recorded in EXPERIMENTS.md §E2E.
//!
//! Run: `make artifacts && cargo run --release --example e2e_rcv1_pipeline`

use std::time::Instant;

use bbit_mh::coordinator::pipeline::{Pipeline, PipelineConfig};
use bbit_mh::coordinator::scheduler::{paper_c_grid, Scheduler, SolverKind, TrainJob};
use bbit_mh::data::expand::{expand_example, ExpandConfig};
use bbit_mh::data::gen::{CorpusConfig, CorpusGenerator};
use bbit_mh::data::libsvm::{ChunkedReader, LibsvmReader, LibsvmWriter};
use bbit_mh::encode::EncoderSpec;
use bbit_mh::encode::expansion::BbitDataset;
use bbit_mh::report::{fnum, Table};
use bbit_mh::runtime::{PjrtRuntime, TrainEngine};
use bbit_mh::solver::linear::FeatureMatrix;
use bbit_mh::util::Rng;

fn main() -> bbit_mh::Result<()> {
    let n_docs: usize = std::env::args()
        .nth(1)
        .and_then(|s| s.parse().ok())
        .unwrap_or(4000);
    let (b, k) = (8u32, 200usize);
    let dim = 1u64 << 30;
    let seed = 0xE2E;
    let dir = std::env::temp_dir().join("bbit_mh_e2e");
    std::fs::create_dir_all(&dir)?;
    let svm_path = dir.join("rcv1_like_expanded.svm");

    // ---- stage 1: generate + expand + write LibSVM ----
    let t0 = Instant::now();
    let base = CorpusGenerator::new(CorpusConfig {
        n_docs,
        vocab: 4000,
        zipf_alpha: 1.05,
        mean_tokens: 30.0,
        class_signal: 0.55,
        pos_fraction: 0.47,
        seed,
    })
    .generate();
    let cfg = ExpandConfig { vocab: 4000, dim, three_way_rate: 30, seed: seed ^ 0xEE };
    cfg.validate()?;
    {
        let mut w = LibsvmWriter::create(&svm_path)?;
        for ex in base.iter() {
            w.write_example(&expand_example(&cfg, &ex))?;
        }
        w.finish()?;
    }
    let gen_s = t0.elapsed().as_secs_f64();
    let bytes = std::fs::metadata(&svm_path)?.len();
    println!(
        "[1] generated + expanded {n_docs} docs -> {} ({:.1} MB) in {gen_s:.2}s",
        svm_path.display(),
        bytes as f64 / 1e6
    );

    // ---- stage 2: stream through the hashing pipeline ----
    let t0 = Instant::now();
    let pipe = Pipeline::new(PipelineConfig::default());
    let source = ChunkedReader::new(LibsvmReader::open(&svm_path)?.binary(), 256);
    let job = EncoderSpec::Bbit { b, k, d: dim, seed: seed ^ 0x4A5E };
    let (hashed, report) = pipe.run(source, &job)?;
    let hashed = hashed.into_bbit()?;
    let hash_s = t0.elapsed().as_secs_f64();
    println!(
        "[2] pipeline: {} docs hashed (b={b}, k={k}) in {hash_s:.2}s wall \
         ({:.2}s read, {:.2} hash-cpu-s across {} workers, {} backpressure stalls)",
        report.docs,
        report.read_seconds,
        report.hash_cpu_seconds,
        report.per_worker_chunks.len(),
        report.backpressure_stalls,
    );
    println!(
        "    packed size: {} KB = {}x reduction vs on-disk LibSVM",
        hashed.codes.ideal_bytes() / 1024,
        bytes / hashed.codes.ideal_bytes().max(1),
    );

    // 50/50 split, as the paper does for rcv1
    let mut rng = Rng::new(seed ^ 0x51);
    let mut order: Vec<usize> = (0..hashed.len()).collect();
    rng.shuffle(&mut order);
    let n_train = hashed.len() / 2;
    let split = |ids: &[usize]| -> BbitDataset {
        let mut pc = bbit_mh::encode::packed::PackedCodes::zeroed(b, k, ids.len());
        let mut labels = Vec::with_capacity(ids.len());
        for (row, &i) in ids.iter().enumerate() {
            pc.copy_row_from(row, &hashed.codes, i);
            labels.push(hashed.labels[i]);
        }
        BbitDataset::new(pc, labels)
    };
    let train = split(&order[..n_train]);
    let test = split(&order[n_train..]);

    // ---- stage 3: PJRT training (the three-layer hot path) ----
    let mut curve = Table::new(
        "PJRT logistic regression (pallas gather kernel -> jax scan -> HLO -> rust PJRT)",
        &["epoch", "sgd steps", "train acc %", "test acc %", "epoch seconds"],
    );
    match PjrtRuntime::cpu(std::path::Path::new("artifacts")) {
        Err(e) => println!("[3] PJRT training skipped (run `make artifacts`): {e}"),
        Ok(rt) => {
            let mut engine = TrainEngine::new(&rt, "train_logistic_b8_k200", "predict_b8_k200")?;
            assert_eq!((engine.b, engine.k), (b, k));
            let train_codes = train.codes_i32(0, train.len());
            let test_codes = test.codes_i32(0, test.len());
            let y: Vec<f32> = train.labels.iter().map(|&l| l as f32).collect();
            let lambda = bbit_mh::solver::sgd::lambda_from_c(1.0, train.len()) as f32;
            for epoch in 1..=8 {
                let t0 = Instant::now();
                let mut i0 = 0usize;
                while i0 < train.len() {
                    let take = (train.len() - i0).min(engine.chunk);
                    engine.train_chunk(
                        &train_codes[i0 * k..(i0 + take) * k],
                        &y[i0..i0 + take],
                        0.5,
                        lambda,
                    )?;
                    i0 += take;
                }
                let secs = t0.elapsed().as_secs_f64();
                let acc = |codes: &[i32], labels: &[i8]| -> bbit_mh::Result<f64> {
                    let m = engine.margins(codes)?;
                    Ok(m.iter()
                        .zip(labels)
                        .filter(|(m, &l)| (**m >= 0.0) == (l > 0))
                        .count() as f64
                        / labels.len() as f64)
                };
                curve.row(&[
                    epoch.to_string(),
                    engine.steps_done().to_string(),
                    fnum(100.0 * acc(&train_codes, &train.labels)?),
                    fnum(100.0 * acc(&test_codes, &test.labels)?),
                    fnum(secs),
                ]);
            }
            println!("[3] {}", curve.render());
        }
    }

    // ---- stage 4: native LIBLINEAR-substrate sweep on the same codes ----
    let t0 = Instant::now();
    let sched = Scheduler::new(bbit_mh::config::available_workers());
    let mut sweep = Table::new(
        "native solvers on the hashed data, paper C grid (b=8, k=200)",
        &["solver", "C", "test acc %", "train seconds"],
    );
    for kind in [SolverKind::SvmDcd, SolverKind::LrNewton] {
        let jobs: Vec<TrainJob> = paper_c_grid()
            .into_iter()
            .map(|c| TrainJob { tag: String::new(), solver: kind, c })
            .collect();
        for o in sched.run_grid(&train, &test, &jobs)? {
            sweep.row(&[
                format!("{kind:?}"),
                o.c.to_string(),
                fnum(100.0 * o.test_accuracy),
                fnum(o.train_seconds),
            ]);
        }
    }
    println!("[4] {}", sweep.render());
    println!(
        "[4] C-sweep wall time {:.2}s — the hashed data was reused for {} trainings \
         (the paper's amortization argument)",
        t0.elapsed().as_secs_f64(),
        2 * paper_c_grid().len(),
    );

    // ---- stage 5: headline ----
    let best: f64 = sweep
        .rows_raw()
        .iter()
        .map(|r| r[2].parse::<f64>().unwrap())
        .fold(f64::MIN, f64::max);
    let _ = train.dot(0, &vec![0.0; train.dim()]); // touch FeatureMatrix to prove linkage
    println!(
        "[5] headline: best test accuracy {best:.2}% at b·k = 8·200 = 1600 bits/doc storage \
         (paper: >90% at k=30/b=12, >95% at k>=300 on real rcv1)"
    );
    std::fs::remove_dir_all(&dir).ok();
    Ok(())
}
