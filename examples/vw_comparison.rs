//! Head-to-head at equal storage: b-bit minwise hashing vs VW feature
//! hashing (the paper's Section 5 punchline, Figures 5–6).
//!
//! Budgets the same number of bits per document for both methods and shows
//! that b-bit minwise hashing wins decisively — VW needs orders of
//! magnitude more storage for the same accuracy.
//!
//! Run: `cargo run --release --example vw_comparison`

use bbit_mh::coordinator::pipeline::{dataset_chunks, Pipeline, PipelineConfig};
use bbit_mh::coordinator::scheduler::{Scheduler, SolverKind, TrainJob};
use bbit_mh::data::expand::{expand_dataset, ExpandConfig};
use bbit_mh::data::gen::{CorpusConfig, CorpusGenerator};
use bbit_mh::encode::EncoderSpec;
use bbit_mh::report::{fnum, Table};
use bbit_mh::util::Rng;

fn main() -> bbit_mh::Result<()> {
    let base = CorpusGenerator::new(CorpusConfig {
        n_docs: 2000,
        vocab: 3000,
        zipf_alpha: 1.05,
        mean_tokens: 30.0,
        class_signal: 0.55,
        pos_fraction: 0.47,
        seed: 0x7E57,
    })
    .generate();
    let cfg = ExpandConfig { vocab: 3000, dim: 1 << 30, three_way_rate: 30, seed: 0xEE };
    let expanded = expand_dataset(&cfg, &base);
    let (train_raw, test_raw) = expanded.split(0.5, &mut Rng::new(9));
    println!(
        "expanded corpus: {} docs, D = 2^30, mean nnz = {:.0}\n",
        expanded.len(),
        expanded.stats().nnz_mean
    );

    let pipe = Pipeline::new(PipelineConfig::default());
    let sched = Scheduler::new(bbit_mh::config::available_workers());
    let c = 1.0;
    let mut t = Table::new(
        "equal-storage comparison (SVM, C=1): bits/doc -> accuracy",
        &["method", "params", "storage bits/doc", "test acc %"],
    );

    // b-bit arm: (b, k) pairs at growing budgets
    for (b, k) in [(1u32, 64usize), (2, 64), (4, 64), (8, 64), (8, 128), (8, 256)] {
        let job = EncoderSpec::Bbit { b, k, d: 1 << 30, seed: 0x4A5E };
        let (tr, _) = pipe.run(dataset_chunks(&train_raw, 256), &job)?;
        let (te, _) = pipe.run(dataset_chunks(&test_raw, 256), &job)?;
        let o = sched.run_grid(
            &tr.into_bbit()?,
            &te.into_bbit()?,
            &[TrainJob { tag: String::new(), solver: SolverKind::SvmDcd, c }],
        )?;
        t.row(&[
            "b-bit minwise".into(),
            format!("b={b} k={k}"),
            (b as u64 * k as u64).to_string(),
            fnum(100.0 * o[0].test_accuracy),
        ]);
    }

    // VW arm: bins at the same bit budgets (32-bit entries, §5.3 accounting)
    for bins in [16usize, 64, 256, 1024, 4096] {
        let job = EncoderSpec::Vw { bins, seed: 0x77 };
        let (tr, _) = pipe.run(dataset_chunks(&train_raw, 256), &job)?;
        let (te, _) = pipe.run(dataset_chunks(&test_raw, 256), &job)?;
        let o = sched.run_grid(
            &tr.into_vw()?,
            &te.into_vw()?,
            &[TrainJob { tag: String::new(), solver: SolverKind::SvmDcd, c }],
        )?;
        t.row(&[
            "VW".into(),
            format!("k={bins} bins"),
            (bins as u64 * 32).to_string(),
            fnum(100.0 * o[0].test_accuracy),
        ]);
    }
    println!("{}", t.render());
    println!(
        "reading: at ~512 bits/doc, 8-bit minwise (k=64) should beat VW with 4096 bins \
         (131072 bits/doc) — the paper's 10-100x storage gap."
    );
    Ok(())
}
