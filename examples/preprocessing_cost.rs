//! Preprocessing-cost walkthrough (the paper's Section 6 / Table 2
//! argument): data loading dominates, hashing is one-time + parallel, and
//! the batched PJRT kernel removes it from the critical path.
//!
//! Run: `make artifacts && cargo run --release --example preprocessing_cost`

use std::time::Instant;

use bbit_mh::coordinator::pipeline::{Pipeline, PipelineConfig};
use bbit_mh::data::expand::{expand_example, ExpandConfig};
use bbit_mh::data::gen::{CorpusConfig, CorpusGenerator};
use bbit_mh::data::libsvm::{ChunkedReader, LibsvmReader, LibsvmWriter};
use bbit_mh::encode::EncoderSpec;
use bbit_mh::hashing::universal::UniversalFamily;
use bbit_mh::runtime::{MinhashEngine, PjrtRuntime, RoutedMinhash};
use bbit_mh::util::Rng;

fn main() -> bbit_mh::Result<()> {
    let n_docs = 3000;
    let k = 512usize;
    let dir = std::env::temp_dir().join("bbit_mh_prep_cost");
    std::fs::create_dir_all(&dir)?;
    let path = dir.join("data.svm");

    // materialize an expanded corpus on disk
    let base = CorpusGenerator::new(CorpusConfig {
        n_docs,
        vocab: 3000,
        zipf_alpha: 1.05,
        mean_tokens: 30.0,
        class_signal: 0.55,
        pos_fraction: 0.47,
        seed: 3,
    })
    .generate();
    let cfg = ExpandConfig { vocab: 3000, dim: 1 << 30, three_way_rate: 30, seed: 0xEE };
    {
        let mut w = LibsvmWriter::create(&path)?;
        for ex in base.iter() {
            w.write_example(&expand_example(&cfg, &ex))?;
        }
        w.finish()?;
    }
    let mb = std::fs::metadata(&path)?.len() as f64 / 1e6;
    println!("on-disk LibSVM: {mb:.1} MB, {n_docs} docs\n");

    // (1) loading
    let t = Instant::now();
    let mut docs = 0;
    for ex in LibsvmReader::open(&path)?.binary() {
        docs += usize::from(!ex?.indices.is_empty());
    }
    let load = t.elapsed().as_secs_f64();
    println!("data loading (stream parse):       {load:.3}s  (1.00x) [{docs} docs]");

    // (2) single-thread hashing — the paper's raw "Preprocessing" column
    for workers in [1, bbit_mh::config::available_workers()] {
        let pipe = Pipeline::new(PipelineConfig { workers, chunk_size: 256, queue_depth: 4 });
        let t = Instant::now();
        let (out, _) = pipe.run(
            ChunkedReader::new(LibsvmReader::open(&path)?.binary(), 256),
            &EncoderSpec::Bbit { b: 16, k, d: 1 << 30, seed: 11 },
        )?;
        let secs = t.elapsed().as_secs_f64();
        assert_eq!(out.len(), n_docs);
        println!(
            "hash k={k}, {workers:>2} worker(s):           {secs:.3}s  ({:.2}x loading)",
            secs / load
        );
    }

    // (3) the PJRT batched kernel (the paper's GPU column analogue), both
    // the naive full-pad path and the size-routed path (§Perf)
    match PjrtRuntime::cpu(std::path::Path::new("artifacts")) {
        Err(e) => println!("PJRT path skipped: {e}"),
        Ok(rt) => {
            let engine = MinhashEngine::new(&rt, "minhash_k512")?;
            let family =
                UniversalFamily::draw(engine.k, engine.d_space, &mut Rng::new(13));
            let t = Instant::now();
            let mut rows = 0usize;
            for chunk in ChunkedReader::new(LibsvmReader::open(&path)?.binary(), engine.batch) {
                let chunk = chunk?;
                let sets: Vec<&[u32]> = chunk
                    .iter()
                    .map(|e| {
                        let n = e.indices.len().min(engine.nnz);
                        &e.indices[..n]
                    })
                    .collect();
                rows += engine.minhash_batch(&sets, &family)?.len() / engine.k;
            }
            let secs = t.elapsed().as_secs_f64();
            println!(
                "hash k=512 via PJRT (pad 2048):    {secs:.3}s  ({:.2}x loading) [{rows} docs]",
                secs / load
            );
            let routed = RoutedMinhash::from_names(&rt, &["minhash_k512_nnz512", "minhash_k512_nnz1024", "minhash_k512"])?;
            let t = Instant::now();
            let mut rows = 0usize;
            for chunk in ChunkedReader::new(LibsvmReader::open(&path)?.binary(), 8192) {
                let chunk = chunk?;
                let sets: Vec<&[u32]> = chunk.iter().map(|e| e.indices.as_slice()).collect();
                rows += routed.minhash_all(&sets, &family)?.len() / routed.k();
            }
            let secs = t.elapsed().as_secs_f64();
            println!(
                "hash k=512 via PJRT (size-routed): {secs:.3}s  ({:.2}x loading) [{rows} docs]",
                secs / load
            );
            println!(
                "\nnote: the PJRT number runs the Pallas kernel in interpret mode on CPU; \
                 it demonstrates the *architecture* (hashing offloaded to one batched \
                 device call per 256 docs). DESIGN.md §6 gives the VMEM/roofline estimate \
                 for real TPU hardware, where this path drops well under loading time \
                 (the paper's GPU sees 1/7th)."
            );
        }
    }
    std::fs::remove_dir_all(&dir).ok();
    Ok(())
}
