#!/usr/bin/env bash
# Append one line per BENCH_*.json to the longitudinal trend log
# (benches/trend/trend.jsonl): {"sha","date","file","result"} — the raw
# scenario JSON nested under "result" so later tooling can slice any key
# without this script knowing the schema.
#
#   bench_trend.sh <trend.jsonl> <BENCH_a.json> [BENCH_b.json ...]
#
# CI calls this after the bench smokes; locally it works the same.  The
# log is append-only and line-oriented, so concurrent branches merge as a
# union and a corrupted line never poisons the rest of the file.
set -euo pipefail

if [ $# -lt 2 ]; then
    echo "usage: $0 <trend.jsonl> <BENCH_*.json ...>" >&2
    exit 2
fi
out="$1"
shift
mkdir -p "$(dirname "$out")"
sha="$(git rev-parse --short HEAD 2>/dev/null || echo unknown)"
date="$(date -u +%Y-%m-%dT%H:%M:%SZ)"

for f in "$@"; do
    if [ ! -s "$f" ]; then
        echo "bench_trend: skipping missing/empty $f" >&2
        continue
    fi
    python3 - "$f" "$sha" "$date" >>"$out" <<'PY'
import json
import sys

path, sha, date = sys.argv[1], sys.argv[2], sys.argv[3]
result = json.load(open(path))
print(json.dumps({"sha": sha, "date": date, "file": path, "result": result},
                 separators=(",", ":")))
PY
    echo "bench_trend: appended $f to $out"
done
