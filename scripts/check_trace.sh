#!/usr/bin/env bash
# Trace smoke (ISSUE 8): run a real one-pass `train --stream` with
# `--trace-out` and `--report-json`, then assert the JSONL span log
# carries every pipeline stage plus the per-epoch training point, and
# that the report dump is machine-readable.
#
# Usage: check_trace.sh [path-to-bbit-mh-binary]
set -euo pipefail

ROOT="$(cd "$(dirname "$0")/.." && pwd)"
BIN="${1:-$ROOT/rust/target/release/bbit-mh}"
[ -x "$BIN" ] || { echo "binary not found: $BIN (run cargo build --release first)" >&2; exit 1; }

TMP="$(mktemp -d)"
trap 'rm -rf "$TMP"' EXIT

"$BIN" gen-data --out "$TMP/data.svm" --n 300 --vocab 500 --seed 8
"$BIN" train --input "$TMP/data.svm" --stream --encoder bbit --b 8 --k 32 \
  --trace-out "$TMP/trace.jsonl" --report-json "$TMP/report.json"

[ -s "$TMP/trace.jsonl" ] || { echo "trace file is empty" >&2; exit 1; }

# every line is a complete JSON object (no torn writes from the
# per-thread buffers)
if grep -vE '^\{.*\}$' "$TMP/trace.jsonl" >/dev/null; then
  echo "trace file has malformed lines:" >&2
  grep -vE '^\{.*\}$' "$TMP/trace.jsonl" >&2
  exit 1
fi

# the ingest pipeline's stage spans and the solver's epoch point
for name in pipeline.run pipeline.read pipeline.parse pipeline.encode \
            pipeline.sink train.epoch; do
  grep -q "\"name\":\"$name\"" "$TMP/trace.jsonl" \
    || { echo "span '$name' missing from the trace:" >&2; cat "$TMP/trace.jsonl" >&2; exit 1; }
done

# stage spans parent under one pipeline.run trace
python3 - "$TMP/trace.jsonl" <<'PY'
import json, sys
events = [json.loads(l) for l in open(sys.argv[1])]
roots = [e for e in events if e["name"] == "pipeline.run"]
assert len(roots) == 1, f"want one pipeline.run root, got {len(roots)}"
root = roots[0]
assert root["parent"] == 0, root
for e in events:
    if e["name"].startswith("pipeline.") and e["name"] != "pipeline.run":
        assert e["trace"] == root["trace"], (e, root)
epochs = [e for e in events if e["name"] == "train.epoch"]
assert epochs and all(e["kind"] == "point" for e in epochs), epochs
print(f"trace OK: {len(events)} events, {len(epochs)} epoch point(s)")
PY

# the report dump is parseable and carries the ingest counters
python3 - "$TMP/report.json" <<'PY'
import json, sys
r = json.load(open(sys.argv[1]))
for key in ("docs", "wall_seconds", "read_seconds", "hash_cpu_seconds"):
    assert key in r, f"report.json missing {key}: {r}"
assert r["docs"] == 300, r["docs"]
print(f"report OK: {r['docs']} docs in {r['wall_seconds']:.3f}s")
PY

echo "check_trace: pipeline spans, epoch points, and report dump all OK"
