#!/usr/bin/env python3
"""Promtool-style Prometheus text-format validator (ISSUE 8).

Mirrors the checks in rust/src/metrics/prom.rs::validate so the CI
scrape gate (scripts/check_metrics.sh) can judge a live /metrics body
without a promtool binary on the runner:

- every sample's metric family has a # TYPE line, emitted before samples;
- one # TYPE per family;
- counter family names end in _total;
- histogram `le` bounds strictly increase and end at +Inf;
- histogram bucket counts are cumulative (non-decreasing);
- the +Inf bucket equals _count, and _sum is present.

Usage: validate_prom.py NAME < exposition.txt
Exits nonzero with a diagnostic on the first violation.
"""
import re
import sys

NAME = sys.argv[1] if len(sys.argv) > 1 else "exposition"
SAMPLE_RE = re.compile(
    r'^([a-zA-Z_:][a-zA-Z0-9_:]*)(\{le="([^"]*)"\})? ([0-9.eE+\-]+|NaN|\+Inf)$'
)


def die(msg: str) -> None:
    sys.exit(f"{NAME}: invalid Prometheus exposition: {msg}")


def family_of(metric: str) -> str:
    for suffix in ("_bucket", "_sum", "_count"):
        if metric.endswith(suffix):
            return metric[: -len(suffix)]
    return metric


types: dict[str, str] = {}
hist: dict[str, dict] = {}  # family -> {"les": [..], "counts": [..], "sum": bool, "count": val}

for lineno, line in enumerate(sys.stdin.read().splitlines(), 1):
    if not line.strip():
        continue
    if line.startswith("# HELP "):
        continue
    if line.startswith("# TYPE "):
        parts = line.split()
        if len(parts) != 4:
            die(f"line {lineno}: malformed TYPE line: {line!r}")
        fam, kind = parts[2], parts[3]
        if kind not in ("counter", "gauge", "histogram"):
            die(f"line {lineno}: unknown type {kind!r} for {fam}")
        if fam in types:
            die(f"line {lineno}: duplicate TYPE for {fam}")
        types[fam] = kind
        if kind == "counter" and not fam.endswith("_total"):
            die(f"line {lineno}: counter {fam} must end in _total")
        if kind == "histogram":
            hist[fam] = {"les": [], "counts": [], "sum": False, "count": None}
        continue
    if line.startswith("#"):
        continue
    m = SAMPLE_RE.match(line)
    if not m:
        die(f"line {lineno}: unparseable sample: {line!r}")
    metric, le, value = m.group(1), m.group(3), m.group(4)
    fam = family_of(metric)
    kind = types.get(fam) or types.get(metric)
    if kind is None:
        die(f"line {lineno}: sample {metric} has no preceding TYPE line")
    if kind != "histogram":
        fam = metric  # _sum/_total suffixes belong to the metric itself
        if le is not None:
            die(f"line {lineno}: le label on non-histogram {metric}")
        continue
    h = hist[fam]
    if metric.endswith("_bucket"):
        if le is None:
            die(f"line {lineno}: histogram bucket without le: {line!r}")
        bound = float("inf") if le == "+Inf" else float(le)
        if h["les"] and not bound > h["les"][-1]:
            die(f"line {lineno}: {fam} le bounds must strictly increase")
        count = float(value)
        if h["counts"] and count < h["counts"][-1]:
            die(f"line {lineno}: {fam} buckets must be cumulative")
        h["les"].append(bound)
        h["counts"].append(count)
    elif metric.endswith("_sum"):
        h["sum"] = True
    elif metric.endswith("_count"):
        h["count"] = float(value)
    else:
        die(f"line {lineno}: stray sample {metric} under histogram {fam}")

for fam, h in hist.items():
    if not h["les"] or h["les"][-1] != float("inf"):
        die(f"histogram {fam} must end with a +Inf bucket")
    if not h["sum"]:
        die(f"histogram {fam} is missing _sum")
    if h["count"] is None:
        die(f"histogram {fam} is missing _count")
    if h["counts"][-1] != h["count"]:
        die(f"histogram {fam}: +Inf bucket {h['counts'][-1]} != _count {h['count']}")

if not types:
    die("no metric families found")
print(f"{NAME}: {len(types)} families OK "
      f"({sum(1 for k in types.values() if k == 'histogram')} histograms)")
