#!/usr/bin/env bash
# Benchmark regression gate: compare a fresh BENCH_*.json against its
# committed baseline in benches/baselines/.
#
#   bench_gate.sh <current.json> <baseline.json>
#
# The baseline declares which dotted keys to watch and in which direction:
#
#   {
#     "provisional": true,            # record-only: print, never fail
#     "tolerance": 0.35,              # fractional band (shared runners are noisy)
#     "higher_is_better": {"train_from_cache.rows_per_s": 100000.0, ...},
#     "lower_is_better":  {"serve.p99_us": 5000, ...},
#     "required": ["train_from_cache.kernel_speedup"]   # keys that must exist
#   }
#
# A non-provisional baseline fails the gate when a watched value regresses
# past tolerance: got < ref*(1-tol) for higher-is-better keys, or
# got > ref*(1+tol) for lower-is-better.  Improvements never fail; to
# ratchet the baseline forward, paste the printed snippet into the
# baseline file (and drop "provisional" once the refs come from real CI
# runs rather than placeholders).
set -euo pipefail

if [ $# -ne 2 ]; then
    echo "usage: $0 <current.json> <baseline.json>" >&2
    exit 2
fi
cur="$1"
base="$2"
if [ ! -s "$cur" ]; then
    echo "bench_gate: current result $cur missing or empty" >&2
    exit 1
fi
if [ ! -s "$base" ]; then
    echo "bench_gate: baseline $base missing or empty" >&2
    exit 1
fi

python3 - "$cur" "$base" <<'PY'
import json
import sys

cur_path, base_path = sys.argv[1], sys.argv[2]
cur = json.load(open(cur_path))
base = json.load(open(base_path))

def lookup(obj, dotted):
    for part in dotted.split("."):
        if not isinstance(obj, dict) or part not in obj:
            return None
        obj = obj[part]
    return obj

provisional = bool(base.get("provisional", False))
tol = float(base.get("tolerance", 0.35))
failures = []
rows = []

for direction, table in (("higher", base.get("higher_is_better", {})),
                         ("lower", base.get("lower_is_better", {}))):
    for key, ref in table.items():
        got = lookup(cur, key)
        if got is None:
            failures.append(f"{key}: missing from {cur_path}")
            continue
        got, ref = float(got), float(ref)
        if direction == "higher":
            floor = ref * (1.0 - tol)
            ok = got >= floor
            bound = f">= {floor:.4g}"
        else:
            ceil = ref * (1.0 + tol)
            ok = got <= ceil
            bound = f"<= {ceil:.4g}"
        rows.append((key, got, ref, bound, ok))
        if not ok:
            failures.append(f"{key}: got {got:.4g}, baseline {ref:.4g} (want {bound})")

for key in base.get("required", []):
    if lookup(cur, key) is None:
        failures.append(f"{key}: required key missing from {cur_path}")

width = max((len(r[0]) for r in rows), default=10)
print(f"bench_gate: {cur_path} vs {base_path} "
      f"(tolerance {tol:.0%}{', PROVISIONAL' if provisional else ''})")
for key, got, ref, bound, ok in rows:
    mark = "ok  " if ok else "FAIL"
    print(f"  {mark} {key:<{width}}  got {got:<12.6g} ref {ref:<12.6g} want {bound}")

if failures and not provisional:
    print(f"bench_gate: {len(failures)} regression(s) past tolerance:", file=sys.stderr)
    for f in failures:
        print(f"  {f}", file=sys.stderr)
    sys.exit(1)

if provisional:
    # Ready-to-commit refs measured on this runner: paste into the baseline
    # (keeping the key sets) and delete "provisional" to arm the gate.
    snippet = {}
    for table in ("higher_is_better", "lower_is_better"):
        keys = base.get(table, {})
        snippet[table] = {k: lookup(cur, k) for k in keys if lookup(cur, k) is not None}
    print("bench_gate: provisional baseline — gate is record-only.  Measured refs:")
    print(json.dumps(snippet, indent=2))
PY
