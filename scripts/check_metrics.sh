#!/usr/bin/env bash
# Scrape-and-validate gate (ISSUE 8): boot a real `bbit-mh serve` backend
# and a `bbit-mh route` tier in front of it, fetch both live /metrics
# bodies over HTTP, and run each through a promtool-style format
# validator (a python re-implementation of the checks in
# rust/src/metrics/prom.rs::validate — TYPE-before-samples, counters end
# in _total, histogram buckets cumulative and capped by +Inf == _count).
#
# Usage: check_metrics.sh [path-to-bbit-mh-binary]
# The binary defaults to rust/target/release/bbit-mh (built by the tier-1
# job before this script runs).
set -euo pipefail

ROOT="$(cd "$(dirname "$0")/.." && pwd)"
BIN="${1:-$ROOT/rust/target/release/bbit-mh}"
[ -x "$BIN" ] || { echo "binary not found: $BIN (run cargo build --release first)" >&2; exit 1; }

TMP="$(mktemp -d)"
PIDS=()
cleanup() {
  for pid in "${PIDS[@]:-}"; do kill "$pid" 2>/dev/null || true; done
  wait 2>/dev/null || true
  rm -rf "$TMP"
}
trap cleanup EXIT

# ---- tiny corpus -> streamed model ----------------------------------
"$BIN" gen-data --out "$TMP/data.svm" --n 200 --vocab 500 --seed 8
"$BIN" train --input "$TMP/data.svm" --stream --encoder bbit --b 8 --k 32 \
  --save-model "$TMP/m.bbmh"

# ---- boot the backend; `serve` blocks on stdin (EOF stops it), so a
# long sleep holds it open from the background ------------------------
( sleep 300 | "$BIN" serve --model "$TMP/m.bbmh" --port 0 --workers 1 ) \
  >"$TMP/serve.out" 2>"$TMP/serve.log" &
PIDS+=($!)

wait_addr() { # wait_addr LOGFILE -> host:port
  local log="$1" addr=""
  for _ in $(seq 1 100); do
    addr="$(grep -oE 'http://[0-9.]+:[0-9]+' "$log" 2>/dev/null | head -1 || true)"
    [ -n "$addr" ] && { echo "${addr#http://}"; return 0; }
    sleep 0.1
  done
  echo "server never printed its address:" >&2
  cat "$log" >&2
  return 1
}
BACKEND="$(wait_addr "$TMP/serve.log")"

# ---- boot the router in front of it ---------------------------------
( sleep 300 | "$BIN" route --backends "$BACKEND" --shards 1 --port 0 ) \
  >"$TMP/route.out" 2>"$TMP/route.log" &
PIDS+=($!)
ROUTER="$(wait_addr "$TMP/route.log")"

fetch() { # fetch host:port/path -> body on stdout, headers to $TMP/hdrs
  curl -sS --max-time 10 -D "$TMP/hdrs" "http://$1"
}

validate() { # validate NAME < body
  python3 "$ROOT/scripts/validate_prom.py" "$1"
}

# ---- both expositions must validate, and every response carries the
# echoed trace id -----------------------------------------------------
for tier in "backend:$BACKEND" "router:$ROUTER"; do
  name="${tier%%:*}"; addr="${tier#*:}"
  body="$TMP/metrics.$name.txt"
  fetch "$addr/metrics" >"$body"
  grep -qi '^x-trace-id:' "$TMP/hdrs" \
    || { echo "$name /metrics response carries no X-Trace-Id echo" >&2; exit 1; }
  validate "$name" <"$body"
done

grep -q '^serve_model_epoch ' "$TMP/metrics.backend.txt" \
  || { echo "backend exposition is missing the serve_model_epoch gauge" >&2; exit 1; }
grep -q '^route_backends_up 1$' "$TMP/metrics.router.txt" \
  || { echo "router exposition should report 1 backend up" >&2; cat "$TMP/metrics.router.txt" >&2; exit 1; }

echo "check_metrics: both /metrics bodies validate (backend $BACKEND, router $ROUTER)"
